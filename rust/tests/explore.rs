//! Integration coverage for the search-driven design-space explorer: the
//! exhaustive cross-check on a grid small enough to prove the argmin, the
//! two-tier ↔ exact equivalence when the sample budget covers every row,
//! the warm-journal re-run (zero fresh simulations), and the budget
//! accounting + determinism of the evolution strategy.

use std::path::PathBuf;

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::sim::{
    check_against_exhaustive, Axis, DesignSpace, DiskCache, ExploreSpec, Explorer, Objective,
    SimEngine, Strategy, Tier, WorkloadKey,
};

fn scratch_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("maple-explore-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Two Table-I datasets (down-scaled) over a base config.
fn two_dataset_space(macs: Vec<usize>) -> DesignSpace {
    DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![
            WorkloadKey::suite("wv", 7, 64),
            WorkloadKey::suite("fb", 7, 64),
        ]))
        .with_axis(Axis::macs_per_pe(macs))
}

#[test]
fn exact_hill_climb_finds_the_exhaustive_argmin_on_a_two_cell_axis() {
    // On a single searchable axis of length 2, the first climb provably
    // evaluates both cells (the start point plus its only neighbour), so
    // the search best IS the exhaustive argmin — not just within the band.
    let engine = SimEngine::new();
    let space = two_dataset_space(vec![1, 32]);
    let spec = ExploreSpec {
        strategy: Strategy::HillClimb,
        tier: Tier::Exact,
        budget: 8,
        ..ExploreSpec::default()
    };
    let result = Explorer::new(&engine, space.clone(), spec).run().unwrap();
    let grid = engine.sweep(&space).unwrap();
    assert_eq!(result.grid_cells, grid.cell_count());
    assert_eq!(result.grid_cells, 4);

    let check = check_against_exhaustive(&result, &grid, 0);
    assert!(check.all_in_band(), "{:?}", check.per_dataset);
    for best in &check.per_dataset {
        assert!(best.argmin_match, "search missed the argmin: {best:?}");
    }
    // Searches stay inside their dataset's sub-grid slice.
    let per = result.grid_cells / 2;
    for (d, s) in result.searches.iter().enumerate() {
        assert_eq!(s.cells, per);
        assert!(s.best_index >= d * per && s.best_index < (d + 1) * per, "{s:?}");
        assert_eq!(s.best_coords[0].axis, "dataset");
        assert_eq!(s.best_coords[0].index, d);
        assert_eq!(s.evals_exact + s.memo_hits, 8, "every call is exact or memoized");
        assert_eq!(s.journal_hits, 0);
    }
}

#[test]
fn two_tier_with_a_full_sample_budget_matches_the_exact_tier() {
    // A sample budget covering every row degenerates the estimate tier to
    // the exact workload, so the two runs walk identical trajectories and
    // agree bit-for-bit on the optimum.
    let engine = SimEngine::new();
    let space = two_dataset_space(vec![1, 2, 4, 8]);
    let base = ExploreSpec { budget: 12, elite: 3, seed: 7, ..ExploreSpec::default() };
    let exact = Explorer::new(
        &engine,
        space.clone(),
        ExploreSpec { tier: Tier::Exact, ..base.clone() },
    )
    .run()
    .unwrap();
    let two = Explorer::new(
        &engine,
        space,
        ExploreSpec { tier: Tier::TwoTier, sample_budget: 1 << 20, ..base },
    )
    .run()
    .unwrap();
    for (e, t) in exact.searches.iter().zip(&two.searches) {
        assert_eq!(e.best_index, t.best_index, "{}", e.dataset);
        assert_eq!(e.best_fitness.to_bits(), t.best_fitness.to_bits(), "{}", e.dataset);
        assert_eq!(e.best, t.best, "{}", e.dataset);
        assert_eq!(t.estimate_fitness, Some(t.best_fitness), "degenerate estimate is exact");
        let e_traj: Vec<(usize, usize)> =
            e.trajectory.iter().map(|p| (p.calls, p.index)).collect();
        let t_traj: Vec<(usize, usize)> =
            t.trajectory.iter().map(|p| (p.calls, p.index)).collect();
        assert_eq!(e_traj, t_traj, "{}", e.dataset);
        assert!(t.evals_exact <= 3, "elite re-scoring is bounded by `elite`");
    }
}

#[test]
fn warm_journal_rerun_answers_every_call_from_disk() {
    let dir = scratch_dir("journal");
    let space = two_dataset_space(vec![1, 2, 4, 8]);
    let spec = ExploreSpec {
        strategy: Strategy::Evolution { mu: 2, lambda: 4 },
        tier: Tier::TwoTier,
        budget: 16,
        elite: 3,
        sample_budget: 32,
        ..ExploreSpec::default()
    };

    let cold_engine = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let cold = Explorer::new(&cold_engine, space.clone(), spec.clone()).run().unwrap();
    assert!(cold.evals_total() > 0);
    assert_eq!(cold.journal_hits(), 0);
    // One journal artifact per tier touched (estimate search + exact elite).
    assert_eq!(cold_engine.disk_cache().unwrap().stats().evals, 2);

    let warm_engine = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let warm = Explorer::new(&warm_engine, space, spec).run().unwrap();
    assert_eq!(warm.evals_total(), 0, "a warm re-run must not simulate");
    assert!(warm.journal_hits() > 0);
    for (c, w) in cold.searches.iter().zip(&warm.searches) {
        assert_eq!(c.best_index, w.best_index, "{}", c.dataset);
        assert_eq!(c.best_fitness.to_bits(), w.best_fitness.to_bits(), "{}", c.dataset);
        assert_eq!(c.best, w.best, "{}", c.dataset);
        let c_traj: Vec<(usize, usize)> =
            c.trajectory.iter().map(|p| (p.calls, p.index)).collect();
        let w_traj: Vec<(usize, usize)> =
            w.trajectory.iter().map(|p| (p.calls, p.index)).collect();
        assert_eq!(c_traj, w_traj, "warm runs walk the cold trajectory");
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn evolution_budget_accounting_is_exact_and_deterministic() {
    let engine = SimEngine::new();
    let space = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 64)]))
        .with_axis(Axis::macs_per_pe(vec![1, 2, 4, 8, 16, 32]))
        .with_axis(Axis::Policy(vec![
            Policy::RoundRobin,
            Policy::Chunked,
            Policy::GreedyBalance,
        ]));
    let spec = ExploreSpec {
        objective: Objective::Edp,
        strategy: Strategy::Evolution { mu: 4, lambda: 8 },
        tier: Tier::TwoTier,
        budget: 40,
        elite: 3,
        sample_budget: 48,
        seed: 11,
    };
    let a = Explorer::new(&engine, space.clone(), spec.clone()).run().unwrap();
    let b = Explorer::new(&engine, space, spec).run().unwrap();

    for s in &a.searches {
        // Every one of the 40 fitness calls is a fresh estimate or a memo
        // hit (no disk cache ⇒ no journal hits), and exact simulations
        // only happen for the elite re-scoring.
        assert_eq!(s.evals_estimate + s.memo_hits, 40, "{s:?}");
        assert_eq!(s.journal_hits, 0);
        assert!(s.evals_exact >= 1 && s.evals_exact <= 3, "{s:?}");
        assert!(s.trajectory.windows(2).all(|p| p[1].fitness < p[0].fitness));
    }
    for (x, y) in a.searches.iter().zip(&b.searches) {
        assert_eq!(x.best_index, y.best_index);
        assert_eq!(x.best_fitness.to_bits(), y.best_fitness.to_bits());
        assert_eq!(x.evals_estimate, y.evals_estimate);
        assert_eq!(x.memo_hits, y.memo_hits);
        assert_eq!(x.evals_exact, y.evals_exact);
    }
    assert!(a.eval_fraction() > 0.0 && a.eval_fraction() <= 1.0);
}
