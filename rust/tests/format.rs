//! Integration tests for the unified [`SparseFormat`] substrate.
//!
//! The offline build has no proptest crate; properties are checked over
//! deterministic SplitMix64-driven case sweeps (DESIGN.md §Dependencies),
//! same discipline as `proptest_invariants.rs`: each test states an
//! invariant and hammers it with many random instances, and failures
//! print the offending case. Storage-footprint formulas are additionally
//! pinned against hand-counted fixtures.

use maple::prelude::*;
use maple::sparse::gen::{generate, Profile};
use maple::sparse::{ConvertCost, SparseMatrix, SplitMix64, StorageWords};

/// Random CSR matrix drawn from a seed-indexed family: uniform, power-law
/// and banded profiles over (mostly rectangular) shapes.
fn arb_matrix(seed: u64) -> Csr {
    let mut r = SplitMix64::new(seed);
    let rows = 4 + r.below(60) as usize;
    let cols = 4 + r.below(60) as usize;
    let cap = rows * cols;
    let nnz = 1 + r.below((cap / 2) as u64) as usize;
    let profile = match r.below(3) {
        0 => Profile::Uniform,
        1 => Profile::PowerLaw { alpha: 0.5 + r.unit_f64() },
        _ => Profile::Banded {
            rel_bandwidth: 0.05 + 0.1 * r.unit_f64(),
            cluster: 1 + r.below(5) as usize,
        },
    };
    generate(rows, cols, nnz, profile, seed.wrapping_mul(0x9E37_79B9))
}

/// The random family plus the shapes it under-samples: strongly tall,
/// strongly wide, and empty matrices.
fn case_matrices() -> Vec<(String, Csr)> {
    let mut cases: Vec<(String, Csr)> =
        (0..32).map(|s| (format!("seed {s}"), arb_matrix(s))).collect();
    cases.push(("tall".into(), generate(70, 3, 40, Profile::Uniform, 11)));
    cases.push(("wide".into(), generate(3, 70, 40, Profile::PowerLaw { alpha: 1.1 }, 12)));
    cases.push(("empty".into(), Csr::zero(6, 9)));
    cases.push(("unit-empty".into(), Csr::zero(1, 1)));
    cases
}

#[test]
fn prop_every_pairwise_conversion_is_an_exact_identity() {
    for (name, a) in case_matrices() {
        let reference = SparseMatrix::Csr(a.clone()).triplets();
        for from in SparseFormat::ALL {
            let enc = SparseMatrix::from_csr(from, &a);
            assert_eq!(enc.format(), from, "{name}");
            assert_eq!(enc.rows(), a.rows(), "{name}: {from}");
            assert_eq!(enc.cols(), a.cols(), "{name}: {from}");
            assert_eq!(enc.nnz(), a.nnz(), "{name}: {from}");
            assert_eq!(enc.to_csr(), a, "{name}: {from} must decode canonically");
            assert_eq!(enc.triplets(), reference, "{name}: {from}");
            for to in SparseFormat::ALL {
                let (out, _) = enc.convert(to);
                assert_eq!(out.format(), to, "{name}: {from}->{to}");
                assert_eq!(out.triplets(), reference, "{name}: {from}->{to}");
                let (back, _) = out.convert(from);
                assert_eq!(back.to_csr(), a, "{name}: {from}->{to}->{from}");
            }
        }
    }
}

#[test]
fn prop_conversion_cost_is_the_sum_of_both_images() {
    for seed in 0..24 {
        let a = arb_matrix(seed);
        for from in SparseFormat::ALL {
            let enc = SparseMatrix::from_csr(from, &a);
            let (same, free) = enc.convert(from);
            assert_eq!(same, enc, "seed {seed}: {from}");
            assert_eq!(free, ConvertCost::default(), "seed {seed}: same-format must be free");
            for to in SparseFormat::ALL {
                if to == from {
                    continue;
                }
                let (out, cost) = enc.convert(to);
                let words = enc.storage_words().total() + out.storage_words().total();
                assert_eq!(cost.dram_words, words, "seed {seed}: {from}->{to}");
                assert_eq!(cost.cycles, words, "seed {seed}: one word per cycle");
            }
        }
    }
}

#[test]
fn storage_footprints_match_hand_counted_fixtures() {
    // 4×5, nnz 6, columns 0..=4: the first four columns share one 4×4
    // block and column 4 opens a second, so `blocked` materialises exactly
    // two blocks. On this shape every closed-form estimate is exact.
    let a = Csr::from_triplets(
        4,
        5,
        vec![(0, 0, 1.0), (0, 4, 2.0), (1, 2, 3.0), (2, 1, 4.0), (3, 3, 5.0), (3, 4, 6.0)],
    );
    let expect = [
        (SparseFormat::Csr, 11, 6),        // nnz + rows + 1 pointer words
        (SparseFormat::Csc, 12, 6),        // nnz + cols + 1 pointer words
        (SparseFormat::Coo, 12, 6),        // two coordinate words per entry
        (SparseFormat::Bitmap, 4, 6),      // 4 rows × ⌈5/32⌉ mask words
        (SparseFormat::BlockedCsr, 4, 32), // 2 ids + ⌈4/4⌉+1 ptrs, 16 values/block
    ];
    for (fmt, index_words, value_words) in expect {
        let got = SparseMatrix::from_csr(fmt, &a).storage_words();
        assert_eq!(got, StorageWords { index_words, value_words }, "{fmt}");
        assert_eq!(got.total(), fmt.estimate_words(4, 5, 6), "{fmt} estimate must be exact here");
    }
}

#[test]
fn prop_closed_form_estimates_are_exact_for_position_free_formats() {
    // csr/csc/coo/bitmap footprints depend only on (rows, cols, nnz) —
    // the closed form the traffic planner uses is exact for any matrix.
    let flat = [SparseFormat::Csr, SparseFormat::Csc, SparseFormat::Coo, SparseFormat::Bitmap];
    for seed in 0..24 {
        let a = arb_matrix(seed);
        for fmt in flat {
            let got = SparseMatrix::from_csr(fmt, &a).storage_words().total();
            let est = fmt.estimate_words(a.rows(), a.cols(), a.nnz() as u64);
            assert_eq!(got, est, "seed {seed}: {fmt}");
        }
    }
}

#[test]
fn blocked_estimate_upper_bounds_the_exact_footprint() {
    // 8×8 identity: eight nonzeros but only two occupied diagonal blocks.
    // The totals-only bound (min(nnz, block slots) = 4) over-counts by
    // design: the traffic plan must be a pure function of workload totals
    // so cold and warm (disk-cached) runs price cells identically.
    let eye = Csr::from_triplets(8, 8, (0..8).map(|i| (i, i, 1.0)).collect());
    let exact = SparseMatrix::from_csr(SparseFormat::BlockedCsr, &eye).storage_words();
    assert_eq!(exact, StorageWords { index_words: 2 + 3, value_words: 32 });
    assert!(SparseFormat::BlockedCsr.estimate_words(8, 8, 8) >= exact.total());
    for seed in 0..24 {
        let a = arb_matrix(seed);
        let est = SparseFormat::BlockedCsr.estimate_words(a.rows(), a.cols(), a.nnz() as u64);
        let got = SparseMatrix::from_csr(SparseFormat::BlockedCsr, &a).storage_words();
        assert!(est >= got.total(), "seed {seed}: {est} < {}", got.total());
    }
}

#[test]
fn format_axis_sweep_is_deterministic_and_csr_matches_formatless() {
    let space = |formats: bool| {
        let mut s = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
            .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 16)]))
            .with_axis(Axis::macs_per_pe(vec![2, 4]));
        if formats {
            s = s.with_axis(Axis::format(SparseFormat::ALL.to_vec()));
        }
        s
    };
    let grid = SimEngine::new().sweep(&space(true)).unwrap();
    assert_eq!(grid.shape(), vec![1, 1, 2, 5, 1]);
    // The CSR point is bit-identical to the formatless sweep; only the
    // expanded config label differs (`+fmt=csr`).
    let plain = SimEngine::new().sweep(&space(false)).unwrap();
    for m in 0..2 {
        let base = &plain.at(&[0, 0, m, 0]).analytic;
        let mut relabeled = grid.at(&[0, 0, m, 0, 0]).analytic.clone();
        assert_eq!(relabeled.config, format!("{}+fmt=csr", base.config), "macs index {m}");
        relabeled.config = base.config.clone();
        assert_eq!(&relabeled, base, "macs index {m}");
    }
    // The whole grid is invariant under the worker-thread count.
    let par = SimEngine::new().with_threads(4).sweep(&space(true)).unwrap();
    assert_eq!(par, grid);
}
