//! Acceptance tests for the out-of-core tiling pipeline: tiles exactly
//! partition the matrix for every generator family, the tiled profiler is
//! bit-identical to the whole-matrix profile across tile shapes and thread
//! counts (including degenerate shapes), the streamed row-group container
//! round-trips and profiles out-of-core under its memory budget, an
//! interrupted tiled profile resumes warm from the partial cache, and the
//! `tile` sweep axis expands with a per-cell scratchpad feasibility gate.
//!
//! Same property-test discipline as `proptest_invariants.rs`: no proptest
//! crate, deterministic SplitMix64-driven case sweeps, failures print the
//! offending seed.

use std::path::PathBuf;

use maple::config::{AcceleratorConfig, ConfigAxis};
use maple::sim::cache::encode_workload;
use maple::sim::{
    profile_container_tiled, profile_workload, profile_workload_tiled,
    profile_workload_tiled_cached, Axis, DesignSpace, DiskCache, EngineError, SimEngine,
    WorkloadKey,
};
use maple::sparse::gen::{generate, Profile};
use maple::sparse::io::{stream_matrix_market, write_matrix_market, MmError, RowGroupFile};
use maple::sparse::{tile, Csr, SplitMix64, TileShape};

/// A fresh per-test scratch directory (tests run concurrently in one
/// process, so the tag keeps them disjoint).
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("maple-tiling-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One matrix from each generator family, plus a rectangular one.
fn family_matrices(seed: u64) -> Vec<(&'static str, Csr)> {
    vec![
        ("uniform", generate(70, 70, 900, Profile::Uniform, seed)),
        ("power-law", generate(64, 64, 800, Profile::PowerLaw { alpha: 0.9 }, seed + 1)),
        (
            "banded",
            generate(80, 80, 700, Profile::Banded { rel_bandwidth: 0.15, cluster: 3 }, seed + 2),
        ),
        ("rect", generate(50, 90, 600, Profile::Uniform, seed + 3)),
    ]
}

#[test]
fn prop_tiles_exactly_partition_nnz_for_every_generator() {
    let shapes = [
        TileShape::new(16, 16),
        TileShape::new(7, 13),
        TileShape::new(1, 64),
        TileShape::new(64, 1),
        TileShape::new(4096, 4096), // larger than the matrix
    ];
    for seed in [3, 19] {
        for (family, a) in family_matrices(seed) {
            for shape in shapes {
                let row_cuts = tile::cuts(a.rows(), shape.rows);
                let col_cuts = tile::cuts(a.cols(), shape.cols);
                let mut nnz = 0usize;
                for rw in row_cuts.windows(2) {
                    for cw in col_cuts.windows(2) {
                        let block = tile::extract_block(&a, rw[0], rw[1], cw[0], cw[1]);
                        nnz += block.nnz();
                        // Blocks carry the tile-local shape.
                        assert!(block.rows() == rw[1] - rw[0] && block.cols() == cw[1] - cw[0]);
                    }
                }
                assert_eq!(
                    nnz,
                    a.nnz(),
                    "{family} seed {seed} tile {shape}: tiles must partition nnz exactly"
                );
                // Row-only and column-only partitions agree too.
                let row_nnz: usize = row_cuts
                    .windows(2)
                    .map(|w| tile::extract_rows(&a, w[0], w[1]).nnz())
                    .sum();
                let col_nnz: usize = col_cuts
                    .windows(2)
                    .map(|w| tile::extract_cols(&a, w[0], w[1]).nnz())
                    .sum();
                assert_eq!(row_nnz, a.nnz(), "{family} seed {seed} tile {shape}");
                assert_eq!(col_nnz, a.nnz(), "{family} seed {seed} tile {shape}");
            }
        }
    }
}

#[test]
fn tiled_profile_is_bit_identical_to_whole_for_every_shape_and_thread_count() {
    let shapes = [
        TileShape::new(32, 32),
        TileShape::new(7, 13),
        TileShape::new(1, 128),
        TileShape::new(128, 1),
        TileShape::new(4096, 4096),
    ];
    for (family, a) in family_matrices(29) {
        if a.rows() != a.cols() {
            continue; // C = A × A needs square A
        }
        let whole = profile_workload(&a, &a);
        let whole_bytes = encode_workload(&whole);
        for shape in shapes {
            for threads in [1, 4] {
                let tiled = profile_workload_tiled(&a, &a, shape, threads);
                assert_eq!(
                    tiled, whole,
                    "{family} tile {shape} x{threads}: tiled profile diverged"
                );
                assert_eq!(
                    tiled.checksum.to_bits(),
                    whole.checksum.to_bits(),
                    "{family} tile {shape} x{threads}: checksum bits diverged"
                );
                assert_eq!(
                    encode_workload(&tiled),
                    whole_bytes,
                    "{family} tile {shape} x{threads}: artifact bytes diverged"
                );
            }
        }
    }
}

#[test]
fn streamed_container_round_trips_and_respects_the_budget() {
    let dir = scratch_dir("container");
    let a = generate(96, 96, 2400, Profile::PowerLaw { alpha: 0.8 }, 41);
    let mtx = dir.join("a.mtx");
    write_matrix_market(&mtx, &a).unwrap();

    // A budget ~¼ of the matrix's resident size forces several groups.
    let resident = ((a.rows() + 1) * 8 + a.nnz() * 8) as u64;
    let budget = resident / 4;
    let stream = stream_matrix_market(&mtx, budget).unwrap();
    assert!(stream.group_count() > 1, "budget {budget} did not force multiple groups");
    let mrg = dir.join("a.mrg");
    let file = RowGroupFile::create(&mrg, stream).unwrap();
    assert_eq!((file.rows(), file.cols(), file.nnz()), (a.rows(), a.cols(), a.nnz()));

    let opened = RowGroupFile::open(&mrg).unwrap();
    assert_eq!(opened.fingerprint(), file.fingerprint());
    let mut covered = 0usize;
    for g in 0..opened.group_count() {
        let slice = opened.load_group(g).unwrap();
        assert_eq!(slice.row_lo, covered, "groups must tile the rows contiguously");
        covered = slice.row_hi;
        assert_eq!(slice.matrix, tile::extract_rows(&a, slice.row_lo, slice.row_hi));
        // The budget contract: each group's resident bytes stay within the
        // per-group target (budget / 4).
        let group_bytes = ((slice.matrix.rows() + 1) * 8 + slice.matrix.nnz() * 8) as u64;
        assert!(group_bytes <= budget / 4, "group {g}: {group_bytes} B > target {} B", budget / 4);
    }
    assert_eq!(covered, a.rows());

    // Column tiles cut across all groups exactly like in-memory extraction.
    for (lo, hi) in [(0, 24), (24, 96), (0, 96), (90, 96)] {
        assert_eq!(opened.load_col_tile(lo, hi).unwrap(), tile::extract_cols(&a, lo, hi));
    }

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn container_profile_matches_whole_and_resumes_warm() {
    let dir = scratch_dir("resume");
    let a = generate(80, 80, 1600, Profile::PowerLaw { alpha: 0.7 }, 53);
    let mtx = dir.join("a.mtx");
    write_matrix_market(&mtx, &a).unwrap();
    let resident = ((a.rows() + 1) * 8 + a.nnz() * 8) as u64;
    let stream = stream_matrix_market(&mtx, resident / 2).unwrap();
    let mrg = dir.join("a.mrg");
    let file = RowGroupFile::create(&mrg, stream).unwrap();

    let disk = DiskCache::new(dir.join("cache")).unwrap();
    let key = format!("tiling-test-{:016x}", file.fingerprint());
    let shape = TileShape::new(16, 24);

    let whole = profile_workload(&a, &a);
    let (cold, cold_stats) = profile_container_tiled(&file, shape, &disk, &key).unwrap();
    assert_eq!(cold, whole, "out-of-core profile diverged from the whole-matrix profile");
    assert_eq!(encode_workload(&cold), encode_workload(&whole));
    assert!(cold_stats.blocks_computed > 0 && cold_stats.blocks_loaded == 0);
    assert!(
        cold_stats.peak_bytes > 0 && cold_stats.peak_bytes < resident * 2,
        "peak gauge {} B is not plausible for a {} B matrix",
        cold_stats.peak_bytes,
        resident
    );

    // Second run: every block comes back warm from the partial cache and
    // the merged artifact is still byte-identical.
    let (warm, warm_stats) = profile_container_tiled(&file, shape, &disk, &key).unwrap();
    assert_eq!(warm, whole);
    assert_eq!(warm_stats.blocks_computed, 0, "warm resume recomputed blocks");
    assert_eq!(warm_stats.blocks_loaded, cold_stats.blocks_computed);

    // The in-memory cached variant interoperates with the same store: it
    // also resumes warm under the same key and shape.
    let (mem, mem_stats) = profile_workload_tiled_cached(&a, &a, shape, 1, Some((&disk, &key)));
    assert_eq!(mem, whole);
    assert_eq!(mem_stats.blocks_computed, 0, "store partials did not carry across entry points");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stream_rejects_budgets_too_small_for_a_row() {
    let dir = scratch_dir("budget");
    let a = generate(40, 40, 600, Profile::Uniform, 61);
    let mtx = dir.join("a.mtx");
    write_matrix_market(&mtx, &a).unwrap();
    match stream_matrix_market(&mtx, 64) {
        Err(MmError::Budget(msg)) => {
            assert!(msg.contains("raise --mem-budget"), "budget error must say how to fix: {msg}")
        }
        other => panic!("tiny budget must fail loudly, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tile_axis_expands_with_a_scratchpad_feasibility_gate() {
    // The tile axis parses next to the other config axes…
    let axis = ConfigAxis::parse("tile", "8x8,16x16").unwrap();
    assert_eq!(axis.name(), "tile");
    assert_eq!(axis.len(), 2);

    // …and sweeping over it yields one expanded config per shape, with the
    // shape in the cell's config name and identical simulated results
    // (tiling changes how the profile is computed, never what it reports).
    let engine = SimEngine::new();
    let key = WorkloadKey::suite("wv", 7, 64);
    let base = AcceleratorConfig::extensor_maple();
    let space = DesignSpace::over(vec![base.clone()])
        .with_axis(Axis::Dataset(vec![key.clone()]))
        .with_axis(Axis::tiling(vec![TileShape::new(8, 8), TileShape::new(16, 16)]))
        .with_axis(Axis::Policy(vec![maple::coordinator::Policy::RoundRobin]));
    let grid = engine.sweep(&space).unwrap();
    assert_eq!(grid.configs.len(), 2);
    assert!(grid.configs[0].ends_with("+tile=8x8"), "{:?}", grid.configs);
    assert!(grid.configs[1].ends_with("+tile=16x16"), "{:?}", grid.configs);
    let (a_cell, b_cell) = (grid.get(0, 0, 0), grid.get(0, 1, 0));
    assert_eq!(a_cell.analytic.cycles, b_cell.analytic.cycles);
    assert_eq!(a_cell.analytic.checksum.to_bits(), b_cell.analytic.checksum.to_bits());

    // A shape whose working set exceeds the config's own scratchpad is
    // rejected loudly at expansion, naming the axis and the config.
    let huge = TileShape::new(1, 10_000_000);
    let infeasible = DesignSpace::over(vec![base])
        .with_axis(Axis::Dataset(vec![key]))
        .with_axis(Axis::tiling(vec![huge]))
        .with_axis(Axis::Policy(vec![maple::coordinator::Policy::RoundRobin]));
    match engine.sweep(&infeasible) {
        Err(EngineError::InvalidAxisPoint(axis, msg)) => {
            assert_eq!(axis, "tile");
            assert!(msg.contains("extensor-maple"), "{msg}");
        }
        other => panic!("infeasible tile must fail expansion, got {other:?}"),
    }
}

#[test]
fn prop_streamed_groups_match_in_memory_rows_across_seeds() {
    // Random (matrix, budget) pairs: the streamed decomposition must agree
    // with in-memory row extraction regardless of where the cuts land.
    for seed in 0..12u64 {
        let mut r = SplitMix64::new(seed ^ 0x7117);
        let n = 24 + r.below(60) as usize;
        let nnz = (n + r.below((n * n / 3) as u64) as usize).max(1);
        let a = generate(n, n, nnz, Profile::PowerLaw { alpha: 0.6 + r.unit_f64() }, seed);
        let dir = scratch_dir(&format!("prop-{seed}"));
        let mtx = dir.join("a.mtx");
        write_matrix_market(&mtx, &a).unwrap();
        let resident = ((a.rows() + 1) * 8 + a.nnz() * 8) as u64;
        // Budgets from "one group" down to "many groups"; the floor keeps
        // the per-group target (budget / 4) above any single row's bytes,
        // so the stream never hits the loud oversized-row rejection here.
        let budget = (resident / (1 + r.below(6))).max((4 * (16 + 8 * n)) as u64);
        let stream = stream_matrix_market(&mtx, budget).unwrap_or_else(|e| {
            panic!("seed {seed}: budget {budget} on {resident} B matrix: {e}")
        });
        let mut covered = 0usize;
        let mut nnz_seen = 0usize;
        for slice in stream {
            let slice = slice.unwrap();
            assert_eq!(slice.row_lo, covered, "seed {seed}");
            covered = slice.row_hi;
            nnz_seen += slice.matrix.nnz();
            assert_eq!(
                slice.matrix,
                tile::extract_rows(&a, slice.row_lo, slice.row_hi),
                "seed {seed}"
            );
        }
        assert_eq!((covered, nnz_seen), (a.rows(), a.nnz()), "seed {seed}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
