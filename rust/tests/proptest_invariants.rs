//! Property-based invariants over randomly generated workloads.
//!
//! The offline build has no proptest crate; properties are checked over
//! deterministic SplitMix64-driven case sweeps (DESIGN.md §Dependencies) —
//! same discipline: each test states an invariant and hammers it with many
//! random instances; failures print the offending seed.

use maple::config::AcceleratorConfig;
use maple::coordinator::{batch_rows_by_reuse, partition, split_wide_rows, Policy};
use maple::gustavson::{
    dense_matmul, max_abs_diff, multiply_count, spgemm_inner, spgemm_outer, spgemm_rowwise,
};
use maple::noc::{Noc, Topology};
use maple::pe::{MaplePe, PeModel, RowProfile};
use maple::sim::profile_workload;
use maple::sparse::gen::{generate, Profile};
use maple::sparse::{Csr, SplitMix64};
use maple::trace::Counters;

/// Random CSR matrix drawn from a seed-indexed family.
fn arb_matrix(seed: u64) -> Csr {
    let mut r = SplitMix64::new(seed);
    let rows = 4 + r.below(60) as usize;
    let cols = 4 + r.below(60) as usize;
    let cap = rows * cols;
    let nnz = 1 + r.below((cap / 2) as u64) as usize;
    let profile = match r.below(3) {
        0 => Profile::Uniform,
        1 => Profile::PowerLaw { alpha: 0.5 + r.unit_f64() },
        _ => Profile::Banded {
            rel_bandwidth: 0.05 + 0.1 * r.unit_f64(),
            cluster: 1 + r.below(5) as usize,
        },
    };
    generate(rows, cols, nnz, profile, seed.wrapping_mul(0x9E37_79B9))
}

#[test]
fn prop_generated_csr_is_always_valid() {
    for seed in 0..200 {
        let a = arb_matrix(seed);
        // try_new re-validates every invariant.
        let b = Csr::try_new(
            a.rows(),
            a.cols(),
            a.row_ptr.clone(),
            a.col_id.clone(),
            a.value.clone(),
        );
        assert!(b.is_ok(), "seed {seed}: {:?}", b.err());
    }
}

#[test]
fn prop_transpose_is_involutive() {
    for seed in 0..100 {
        let a = arb_matrix(seed);
        assert_eq!(a.transpose().transpose(), a, "seed {seed}");
    }
}

#[test]
fn prop_all_dataflows_agree() {
    for seed in 0..60 {
        let a = arb_matrix(seed);
        let b = arb_matrix(seed + 1000);
        if a.cols() != b.rows() {
            continue;
        }
        let oracle = dense_matmul(&a, &b);
        for (name, c) in [
            ("rowwise", spgemm_rowwise(&a, &b)),
            ("inner", spgemm_inner(&a, &b)),
            ("outer", spgemm_outer(&a, &b)),
        ] {
            assert!(max_abs_diff(&c, &oracle) < 1e-3, "seed {seed}: {name} diverges");
        }
    }
}

#[test]
fn prop_profile_matches_reference() {
    for seed in 0..80 {
        let a = arb_matrix(seed);
        if a.rows() != a.cols() {
            continue;
        }
        let w = profile_workload(&a, &a);
        let c = spgemm_rowwise(&a, &a);
        assert_eq!(w.out_nnz, c.nnz() as u64, "seed {seed}");
        assert_eq!(w.total_products, multiply_count(&a, &a), "seed {seed}");
    }
}

#[test]
fn prop_maple_functional_pe_equals_reference() {
    for seed in 0..40 {
        let a = arb_matrix(seed);
        if a.rows() != a.cols() {
            continue;
        }
        let c_ref = spgemm_rowwise(&a, &a);
        let pe = MaplePe::from_config(&AcceleratorConfig::matraptor_maple());
        let mut counters = Counters::default();
        for i in 0..a.rows() {
            let (cols, vals, _) = pe.simulate_row(&a, &a, i, &mut counters);
            assert_eq!(cols.as_slice(), c_ref.row_cols(i), "seed {seed} row {i}");
            for (v, r) in vals.iter().zip(c_ref.row_values(i)) {
                assert!((v - r).abs() < 1e-3, "seed {seed} row {i}");
            }
        }
    }
}

#[test]
fn prop_partition_is_a_bijection() {
    let mut rng = SplitMix64::new(42);
    for _ in 0..100 {
        let rows = 1 + rng.below(500) as usize;
        let pes = 1 + rng.below(32) as usize;
        let profiles: Vec<RowProfile> = (0..rows)
            .map(|_| RowProfile {
                a_nnz: rng.below(16) as u32,
                products: rng.below(1000),
                out_nnz: rng.below(100) as u32,
            })
            .collect();
        for policy in [Policy::RoundRobin, Policy::Chunked, Policy::GreedyBalance] {
            let part = partition(policy, pes, &profiles);
            let mut seen = vec![0u8; rows];
            for a in &part.assignments {
                for &r in a {
                    seen[r as usize] += 1;
                }
            }
            assert!(seen.iter().all(|&s| s == 1), "{policy:?}: not a bijection");
        }
    }
}

#[test]
fn prop_split_preserves_totals() {
    let mut rng = SplitMix64::new(7);
    for _ in 0..200 {
        let profiles: Vec<RowProfile> = (0..1 + rng.below(50) as usize)
            .map(|_| RowProfile {
                a_nnz: 1 + rng.below(40) as u32,
                products: rng.below(100_000),
                out_nnz: rng.below(10_000) as u32,
            })
            .collect();
        let max_products = 1 + rng.below(5000);
        let split = split_wide_rows(&profiles, max_products);
        let tp: u64 = profiles.iter().map(|p| p.products).sum();
        let ts: u64 = split.iter().map(|p| p.products).sum();
        let op: u64 = profiles.iter().map(|p| p.out_nnz as u64).sum();
        let os: u64 = split.iter().map(|p| p.out_nnz as u64).sum();
        assert_eq!(tp, ts, "products conserved");
        assert_eq!(op, os, "out_nnz conserved");
        assert!(split.iter().all(|p| p.products <= max_products));
    }
}

#[test]
fn prop_batches_cover_exactly_once() {
    let mut rng = SplitMix64::new(11);
    for _ in 0..100 {
        let n = 1 + rng.below(300) as usize;
        let rows: Vec<u32> = (0..n as u32).collect();
        let profiles: Vec<RowProfile> = (0..n)
            .map(|_| RowProfile { a_nnz: 1, products: rng.below(4000), out_nnz: 10 })
            .collect();
        let max_batch = 1 + rng.below(16) as usize;
        let batches = batch_rows_by_reuse(&rows, &profiles, max_batch);
        let mut covered = 0usize;
        let mut last_end = 0usize;
        for b in &batches {
            assert_eq!(b.start, last_end, "batches must be contiguous");
            assert!(b.len() <= max_batch);
            covered += b.len();
            last_end = b.end;
        }
        assert_eq!(covered, n);
    }
}

#[test]
fn prop_counters_scale_linearly_with_repeated_rows() {
    // Cost-model action counts must be a pure function of the profile:
    // counting a row twice doubles every counter.
    let pe = MaplePe::from_config(&AcceleratorConfig::extensor_maple());
    let mut rng = SplitMix64::new(23);
    for _ in 0..100 {
        let p = RowProfile {
            a_nnz: 1 + rng.below(30) as u32,
            products: 1 + rng.below(5000),
            out_nnz: 1 + rng.below(2000) as u32,
        };
        let mut c1 = Counters::default();
        pe.row_cost(&p, &mut c1);
        let mut c2 = Counters::default();
        pe.row_cost(&p, &mut c2);
        pe.row_cost(&p, &mut c2);
        let mut doubled = c1.clone();
        doubled.merge(&c1);
        assert_eq!(c2, doubled);
    }
}

#[test]
fn prop_mesh_hops_geometry_invariants() {
    // `Noc::hops` on a 2-D XY mesh must behave like a metric with a
    // one-cycle NIC floor: symmetric, triangle inequality, bounded by the
    // mesh diameter `width + height − 2`, and self-delivery still costs
    // one hop (the NIC traversal).
    let mut rng = SplitMix64::new(97);
    for case in 0..300 {
        let width = 1 + rng.below(16) as usize;
        let height = 1 + rng.below(16) as usize;
        let noc = Noc::new(Topology::Mesh { width, height });
        let n = noc.endpoints();
        let pick = |r: &mut SplitMix64| r.below(n as u64) as usize;
        let (s, d, m) = (pick(&mut rng), pick(&mut rng), pick(&mut rng));
        let tag = format!("case {case}: {width}x{height} s={s} d={d} m={m}");
        // Self-delivery floor.
        assert_eq!(noc.hops(s, s), 1, "{tag}");
        // Symmetry.
        assert_eq!(noc.hops(s, d), noc.hops(d, s), "{tag}");
        // Triangle inequality (holds with the floor: each leg ≥ its
        // Manhattan part and ≥ 1).
        assert!(noc.hops(s, d) <= noc.hops(s, m) + noc.hops(m, d), "{tag}");
        // Diameter bound, with the floor for the degenerate 1×1 mesh.
        let diameter = (width + height - 2).max(1) as u64;
        assert!(noc.hops(s, d) <= diameter, "{tag}");
        assert!(noc.hops(s, d) >= 1, "{tag}");
    }
    // The diameter bound is tight: opposite corners meet it exactly.
    let noc = Noc::new(Topology::Mesh { width: 7, height: 5 });
    assert_eq!(noc.hops(0, 7 * 5 - 1), (7 + 5 - 2) as u64);
}

#[test]
fn prop_energy_monotone_in_counters() {
    use maple::energy::{BufferSizes, EnergyBreakdown, TechModel};
    let t = TechModel::tech45();
    let sizes = BufferSizes {
        pe_buffer_bytes: 48 << 10,
        l1_bytes: 256 << 10,
        pob_bytes: 1 << 20,
        reg_bytes: 2048,
    };
    let mut rng = SplitMix64::new(31);
    for _ in 0..100 {
        let c1 = Counters {
            mac_mul: rng.below(1000),
            dram_read: rng.below(1000),
            l1_read: rng.below(1000),
            queue_write: rng.below(1000),
            ..Default::default()
        };
        let mut c2 = c1.clone();
        c2.mac_mul += 1 + rng.below(100);
        c2.dram_read += 1;
        let e1 = EnergyBreakdown::from_counters(&c1, &t, &sizes);
        let e2 = EnergyBreakdown::from_counters(&c2, &t, &sizes);
        assert!(e2.total_pj() > e1.total_pj());
    }
}
