//! Property coverage for the sampled statistical profiler
//! (`profile_workload_sampled`) against the exact pass: the exact-by-
//! construction fields really are exact, the claimed out-nnz error bound
//! holds, runs are deterministic for a fixed `(budget, seed)`, and a
//! budget that covers every row degenerates to the exact profile verbatim.
//!
//! Same property-test discipline as `cache.rs`: no proptest crate,
//! deterministic SplitMix64-driven case sweeps, failures print the
//! offending seed.

use maple::sim::{estimate_in_band, profile_workload, profile_workload_sampled, ESTIMATE_BAND};
use maple::sparse::gen::{generate, Profile};
use maple::sparse::{Csr, SplitMix64};

/// Random square CSR from a seed, cycling through the three structural
/// families (uniform / power-law / banded) so every ratio-estimator regime
/// is hit.
fn arb_square(seed: u64) -> Csr {
    let mut r = SplitMix64::new(seed);
    let n = 40 + r.below(160) as usize;
    let nnz = (n + r.below((n * n / 8).max(1) as u64) as usize).min(n * n);
    let profile = match r.below(3) {
        0 => Profile::Uniform,
        1 => Profile::PowerLaw { alpha: 0.6 + r.unit_f64() },
        _ => Profile::Banded { rel_bandwidth: 0.1, cluster: 1 + r.below(4) as usize },
    };
    generate(n, n, nnz.max(1), profile, seed.wrapping_mul(0x9E37_79B9))
}

#[test]
fn prop_sampled_profile_keeps_exact_fields_exact_and_bounds_honest() {
    for seed in 0..48 {
        let a = arb_square(seed);
        let exact = profile_workload(&a, &a);
        for budget in [9usize, 24, 72] {
            let est = profile_workload_sampled(&a, &a, budget, seed);
            let w = &est.workload;
            // The cheap pass is exact: dimensions, nnz, and product mass.
            assert_eq!(w.rows, exact.rows, "seed {seed} budget {budget}");
            assert_eq!(w.cols, exact.cols);
            assert_eq!(w.rows_b, exact.rows_b);
            assert_eq!(w.nnz_a, exact.nnz_a);
            assert_eq!(w.nnz_b, exact.nnz_b);
            assert_eq!(w.total_products, exact.total_products);
            for (i, (p, q)) in w.profiles.iter().zip(&exact.profiles).enumerate() {
                assert_eq!(p.a_nnz, q.a_nnz, "seed {seed} row {i}");
                assert_eq!(p.products, q.products, "seed {seed} row {i}");
                // Estimated rows stay inside the structural caps.
                assert!(p.out_nnz as u64 <= p.products.min(w.cols as u64));
            }
            // The claimed error band must cover the measured error.
            let measured = (w.out_nnz as f64 - exact.out_nnz as f64).abs();
            let claimed = est.out_nnz_rel_err * (w.out_nnz.max(1)) as f64;
            assert!(
                measured <= claimed + 1e-9,
                "seed {seed} budget {budget}: |{} - {}| = {measured} > claimed {claimed}",
                w.out_nnz,
                exact.out_nnz,
            );
            // Budget accounting and stratum tiling.
            assert!(est.sampled_rows <= budget.max(1), "seed {seed} budget {budget}");
            assert_eq!(est.strata.first().expect("strata non-empty").rows.start, 0);
            assert_eq!(est.strata.last().expect("strata non-empty").rows.end, w.rows);
            for pair in est.strata.windows(2) {
                assert_eq!(pair[0].rows.end, pair[1].rows.start, "seed {seed}");
            }
            // Determinism: a fixed (budget, seed) reproduces every bit.
            let again = profile_workload_sampled(&a, &a, budget, seed);
            assert_eq!(again, est, "seed {seed} budget {budget}");
            assert_eq!(again.workload.checksum.to_bits(), w.checksum.to_bits());
        }
    }
}

#[test]
fn full_budget_degenerates_to_the_exact_profile() {
    for seed in [1u64, 13, 27] {
        let a = arb_square(seed);
        let exact = profile_workload(&a, &a);
        for budget in [a.rows(), a.rows() + 100, usize::MAX] {
            let est = profile_workload_sampled(&a, &a, budget, seed);
            assert!(est.exact, "seed {seed}");
            assert_eq!(est.workload, exact, "seed {seed}");
            assert_eq!(est.workload.checksum.to_bits(), exact.checksum.to_bits());
            assert_eq!(est.out_nnz_rel_err, 0.0);
            assert_eq!(est.sampled_rows, a.rows());
        }
    }
}

#[test]
fn dominant_rows_are_always_profiled_exactly() {
    // One row holding half the matrix's work: the stratified sampler must
    // include it (each stratum force-includes its heaviest row), so its
    // profile is never extrapolated.
    let mut t: Vec<(u32, u32, f32)> = (0..300u32).map(|j| (7, j, 1.0)).collect();
    for i in 0..300u32 {
        if i != 7 {
            t.push((i, (i * 3) % 300, 0.5));
        }
    }
    let a = Csr::from_triplets(300, 300, t);
    let exact = profile_workload(&a, &a);
    let heavy = (0..300).max_by_key(|&i| exact.profiles[i].products).expect("rows");
    assert_eq!(heavy, 7);
    for seed in 0..8 {
        let est = profile_workload_sampled(&a, &a, 32, seed);
        assert!(!est.exact);
        assert_eq!(est.workload.profiles[7], exact.profiles[7], "seed {seed}");
    }
}

#[test]
fn rectangular_and_empty_workloads_sample_cleanly() {
    let a = generate(30, 50, 200, Profile::Uniform, 5);
    let b = generate(50, 20, 180, Profile::Uniform, 9);
    let exact = profile_workload(&a, &b);
    for (budget, seed) in [(8usize, 3u64), (16, 11)] {
        let est = profile_workload_sampled(&a, &b, budget, seed);
        assert_eq!(est.workload.rows, 30);
        assert_eq!(est.workload.cols, 20);
        assert_eq!(est.workload.rows_b, 50);
        assert_eq!(est.workload.total_products, exact.total_products);
        let measured = (est.workload.out_nnz as f64 - exact.out_nnz as f64).abs();
        let claimed = est.out_nnz_rel_err * (est.workload.out_nnz.max(1)) as f64;
        assert!(measured <= claimed + 1e-9, "budget {budget} seed {seed}");
    }

    let z = Csr::zero(9, 9);
    let est = profile_workload_sampled(&z, &z, 3, 1);
    assert_eq!(est.workload.out_nnz, 0);
    assert_eq!(est.workload.total_products, 0);
    assert_eq!(est.out_nnz_rel_err, 0.0);
    assert_eq!(est.workload.checksum, 0.0);
}

#[test]
fn estimate_band_semantics() {
    assert_eq!(ESTIMATE_BAND, 0.10);
    assert!(estimate_in_band(100.0, 109.0));
    assert!(!estimate_in_band(100.0, 111.0));
    // Absolute floor of 1 near zero: ±0.1 is fine, ±0.5 is not.
    assert!(estimate_in_band(0.0, 0.05));
    assert!(!estimate_in_band(0.0, 0.5));
}
