//! Sharded-sweep contracts: shard artifacts round-trip bit-exactly, merge
//! reassembles the unsharded grid byte-for-byte, and every incompatible or
//! incomplete shard set is rejected loudly.

use maple::config::AcceleratorConfig;
use maple::sim::cache::{decode_shard, encode_shard};
use maple::sim::shard::{self, ShardError, ShardSpec};
use maple::sim::{Axis, CellModel, DesignSpace, SimEngine, SweepShard, WorkloadKey};

/// A small but representative space: two datasets × one base config ×
/// three MACs points × one policy = 6 cells, with the DES attached so the
/// optional `DesResult` section of the codec is exercised.
fn space() -> DesignSpace {
    DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![
            WorkloadKey::suite("wv", 7, 64),
            WorkloadKey::suite("fb", 7, 64),
        ]))
        .with_axis(Axis::macs_per_pe(vec![2, 4, 8]))
        .with_cell_model(CellModel::Both)
}

fn shards_of(engine: &SimEngine, spec: &DesignSpace, count: usize) -> Vec<SweepShard> {
    (0..count)
        .map(|i| engine.sweep_shard(spec, ShardSpec::new(i, count).unwrap()).unwrap())
        .collect()
}

#[test]
fn shard_codec_round_trips_bit_exact() {
    let engine = SimEngine::new();
    let spec = space();
    // Full grid (1 shard), split cells (3 shards), and empty trailing
    // ranges (more shards than cells) all round-trip.
    for count in [1, 3, 8] {
        for s in shards_of(&engine, &spec, count) {
            let bytes = encode_shard(&s);
            let d = decode_shard(&bytes).unwrap();
            assert_eq!(d, s, "{count}-way shard {}", s.spec);
            // Checksum bits survive exactly, and re-encoding is stable.
            for (a, b) in s.cells.iter().zip(&d.cells) {
                assert_eq!(a.analytic.checksum.to_bits(), b.analytic.checksum.to_bits());
            }
            assert_eq!(encode_shard(&d), bytes);
        }
    }
    // 8-way over 6 cells: the trailing shards really were empty.
    let eight = shards_of(&engine, &spec, 8);
    assert!(eight[6].cells.is_empty() && eight[7].cells.is_empty());
    assert_eq!(eight.iter().map(|s| s.cells.len()).sum::<usize>(), 6);
}

#[test]
fn corrupt_shard_artifacts_never_decode() {
    let engine = SimEngine::new();
    let spec = DesignSpace::paper(vec![WorkloadKey::suite("wv", 7, 64)]);
    let shard = engine.sweep_shard(&spec, ShardSpec::new(0, 2).unwrap()).unwrap();
    let clean = encode_shard(&shard);
    for pos in (0..clean.len()).step_by(7) {
        let mut bad = clean.clone();
        bad[pos] ^= 0x20;
        assert!(decode_shard(&bad).is_err(), "flip at byte {pos} went undetected");
    }
    for cut in [0, 11, 27, 28, clean.len() / 2, clean.len() - 1] {
        assert!(decode_shard(&clean[..cut]).is_err(), "cut at {cut}");
    }
}

#[test]
fn merged_shards_equal_the_unsharded_sweep() {
    let engine = SimEngine::new();
    let spec = space();
    let reference = engine.sweep(&spec).unwrap();
    for count in [2, 3] {
        let shards = shards_of(&engine, &spec, count);
        let merged = shard::merge(&shards).unwrap();
        // `SweepResult` equality is bit-for-bit over every cell (SimResult,
        // DES results, coordinates) — the byte-identity contract.
        assert_eq!(merged, reference, "{count}-way merge");
        for idx in 0..reference.cell_count() {
            assert_eq!(
                merged.cell(idx).analytic.checksum.to_bits(),
                reference.cell(idx).analytic.checksum.to_bits()
            );
        }
    }
    // The same holds through the on-disk artifact: encode, decode, merge.
    let shards = shards_of(&engine, &spec, 2);
    let reloaded: Vec<SweepShard> =
        shards.iter().map(|s| decode_shard(&encode_shard(s)).unwrap()).collect();
    assert_eq!(shard::merge(&reloaded).unwrap(), reference);
}

#[test]
fn merge_rejects_incomplete_or_incompatible_sets() {
    let engine = SimEngine::new();
    let spec = space();
    let three = shards_of(&engine, &spec, 3);

    // Gap: shard 1 of 3 missing.
    let gapped = vec![three[0].clone(), three[2].clone()];
    match shard::merge(&gapped) {
        Err(ShardError::MissingShards { missing, count }) => {
            assert_eq!((missing, count), (vec![1], 3));
        }
        other => panic!("expected MissingShards, got {other:?}"),
    }

    // Overlap: shard 0 twice.
    let dup = vec![three[0].clone(), three[0].clone(), three[1].clone(), three[2].clone()];
    assert!(matches!(
        shard::merge(&dup),
        Err(ShardError::DuplicateShard { index: 0, count: 3 })
    ));

    // Mixed split widths of the same space: same fingerprint, caught by
    // the count check.
    let two = shards_of(&engine, &spec, 2);
    let mixed = vec![two[0].clone(), three[1].clone(), three[2].clone()];
    assert!(matches!(shard::merge(&mixed), Err(ShardError::CountMismatch { .. })));

    // A different design space: caught by the fingerprint before anything
    // else (same shard position, same cell count, different macs axis).
    let other_spec = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![
            WorkloadKey::suite("wv", 7, 64),
            WorkloadKey::suite("fb", 7, 64),
        ]))
        .with_axis(Axis::macs_per_pe(vec![2, 4, 16]))
        .with_cell_model(CellModel::Both);
    let foreign = engine.sweep_shard(&other_spec, ShardSpec::new(1, 3).unwrap()).unwrap();
    let crossed = vec![three[0].clone(), foreign, three[2].clone()];
    assert!(matches!(
        shard::merge(&crossed),
        Err(ShardError::FingerprintMismatch { .. })
    ));

    // A tampered range (fields are public): all indices present, but the
    // cells no longer tile the grid.
    let mut tampered = shards_of(&engine, &spec, 2);
    tampered[1].start += 1;
    assert!(matches!(
        shard::merge(&tampered),
        Err(ShardError::RangeMismatch { index: 1, .. })
    ));

    // Profile chunking must agree across shards (checksum bits depend on
    // it), even though it is not part of the space fingerprint.
    let chunked_engine = SimEngine::new().with_profile_threads(4);
    let mut mixed_chunks = shards_of(&engine, &spec, 2);
    mixed_chunks[1] =
        chunked_engine.sweep_shard(&spec, ShardSpec::new(1, 2).unwrap()).unwrap();
    assert!(matches!(
        shard::merge(&mixed_chunks),
        Err(ShardError::Incompatible(_))
    ));
}

#[test]
fn shard_dir_round_trip_and_loud_failures() {
    let dir = std::env::temp_dir().join(format!("maple-shard-dir-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let engine = SimEngine::new();
    let spec = space();
    let shards = shards_of(&engine, &spec, 2);
    for s in &shards {
        let path = s.write_to(&dir).unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(), s.file_name());
    }
    // Foreign non-shard files and stale old-codec-version artifacts are
    // ignored by discovery — a codec bump starts cold next to old files.
    std::fs::write(dir.join("notes.txt"), b"not a shard").unwrap();
    std::fs::write(dir.join("shard-0000-of-0002.v0.mshd"), b"stale codec version").unwrap();
    let loaded = shard::read_dir(&dir).unwrap();
    assert_eq!(loaded, shards);
    assert_eq!(shard::merge(&loaded).unwrap(), engine.sweep(&spec).unwrap());

    // Re-running a shard overwrites its own artifact (same canonical name).
    shards[0].write_to(&dir).unwrap();
    assert_eq!(shard::read_dir(&dir).unwrap().len(), 2);

    // A corrupt artifact is a hard error, not a silent skip.
    let victim = dir.join(shards[1].file_name());
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&victim, &bytes).unwrap();
    assert!(matches!(shard::read_dir(&dir), Err(ShardError::Artifact { .. })));

    // An empty directory has no shards to merge.
    let empty = dir.join("empty");
    std::fs::create_dir_all(&empty).unwrap();
    assert!(matches!(shard::read_dir(&empty), Err(ShardError::NoShards(_))));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ledger_accepts_duplicates_and_rejects_conflicts() {
    use maple::sim::service::{SubmissionLedger, SubmitError, SubmitOutcome};
    let engine = SimEngine::new();
    let spec = space();
    let shards = shards_of(&engine, &spec, 3);
    let mut ledger = SubmissionLedger::new(shards[0].fingerprint, 3, shards[0].total_cells(), 1);

    // First valid submission wins.
    let bytes0 = encode_shard(&shards[0]);
    assert_eq!(ledger.offer(&bytes0).unwrap(), (0, SubmitOutcome::Accepted));
    // An identical resubmission is an idempotent duplicate, not an error.
    assert_eq!(ledger.offer(&bytes0).unwrap(), (0, SubmitOutcome::Duplicate));
    // A re-run of the same cells on a slower machine differs only in the
    // volatile meta stats — canonically still the same shard.
    let mut slower = shards[0].clone();
    slower.meta.wall_ms += 12_345;
    slower.meta.disk_hits += 2;
    assert_eq!(ledger.offer(&encode_shard(&slower)).unwrap(), (0, SubmitOutcome::Duplicate));
    assert_eq!(ledger.duplicates(), 2);

    // A byte-divergent result for the same range is a loud conflict: the
    // first valid submission stays, the divergent one is refused.
    let mut forged = shards[0].clone();
    forged.cells[0].analytic.cycles_compute += 1;
    match ledger.offer(&encode_shard(&forged)) {
        Err(SubmitError::Conflict { index: 0 }) => {}
        other => panic!("expected Conflict, got {other:?}"),
    }
    assert_eq!(ledger.rejected(), 1);
    assert_eq!(ledger.completed(), 1);

    // A shard computed under different profile chunking has different
    // checksum bits by construction — refused before it can conflict.
    let chunked = SimEngine::new().with_profile_threads(4);
    let wrong = chunked.sweep_shard(&spec, ShardSpec::new(1, 3).unwrap()).unwrap();
    assert!(matches!(
        ledger.offer(&encode_shard(&wrong)),
        Err(SubmitError::ProfileThreads { expected: 1, found: 4 })
    ));

    // Completing the set merges exactly the unsharded sweep.
    assert_eq!(ledger.offer(&encode_shard(&shards[1])).unwrap(), (1, SubmitOutcome::Accepted));
    assert!(!ledger.is_complete());
    assert_eq!(ledger.missing(), vec![2]);
    assert_eq!(ledger.offer(&encode_shard(&shards[2])).unwrap(), (2, SubmitOutcome::Accepted));
    assert!(ledger.is_complete());
    assert_eq!(shard::merge(&ledger.shards()).unwrap(), engine.sweep(&spec).unwrap());
}

#[test]
fn concurrent_shard_writers_leave_one_valid_artifact() {
    let dir = std::env::temp_dir().join(format!("maple-shard-race-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let engine = SimEngine::new();
    let spec = space();
    let shard0 = engine.sweep_shard(&spec, ShardSpec::new(0, 2).unwrap()).unwrap();
    // Eight racing writers of the same artifact (the coordinator-restart /
    // re-run scenario): whoever wins, the published file must be complete
    // and decodable, with no temp droppings from the losers.
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| shard0.write_to(&dir).unwrap());
        }
    });
    let loaded = shard::read_dir(&dir).unwrap();
    assert_eq!(loaded, vec![shard0.clone()]);
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .filter(|n| *n != shard0.file_name())
        .collect();
    assert_eq!(leftovers, Vec::<String>::new(), "losing writers left temp files");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn sharding_profiles_only_touched_datasets() {
    // 6 cells over (wv, fb): shard 0/2 covers cells 0..3 — all of wv plus
    // none of fb's range would be wrong; the boundary is inside wv×macs
    // only when counts align. With 3 macs points per dataset, cells 0..3
    // are exactly dataset wv; the shard must not profile fb at all.
    let engine = SimEngine::new();
    let spec = space();
    let s0 = engine.sweep_shard(&spec, ShardSpec::new(0, 2).unwrap()).unwrap();
    assert_eq!(s0.range(), 0..3);
    assert_eq!(engine.profiles_run(), 1, "shard 0 must profile only wv");
    let s1 = engine.sweep_shard(&spec, ShardSpec::new(1, 2).unwrap()).unwrap();
    assert_eq!(s1.range(), 3..6);
    assert_eq!(engine.profiles_run(), 2, "shard 1 adds only fb");
    // Meta reflects the per-shard deltas.
    assert_eq!(s0.meta.profiles_run, 1);
    assert_eq!(s1.meta.profiles_run, 1);
    assert_eq!(s0.meta.profile_threads, 1);
}
