//! Acceptance tests for `maple vet`: the determinism lint over the crate
//! sources and the bounded model checker over the lease/ledger protocol.
//!
//! The contract under test, end to end: the repo tip is lint-clean with
//! only justified pragmas; the default 3-shard × 2-worker model space is
//! exhausted with every invariant proved; and each seeded protocol mutant
//! is caught with a counterexample whose fault plan, replayed through the
//! *real* `run_chaos` harness over loopback TCP, ends in a loud typed
//! `ServiceError::Incomplete` — never a silent divergence.

use std::path::Path;

use maple::analysis::{check, lint_path, Invariant, ModelSpec, Mutation};
use maple::config::AcceleratorConfig;
use maple::sim::{
    run_chaos, Axis, ChaosSpec, DesignSpace, FaultPlan, LeasePolicy, ServiceConfig, ServiceError,
    SimEngine, WorkloadKey,
};

/// Integration tests run with the crate root as cwd, so `src` is the
/// crate's own source tree — `vet` lints the code that built it.
fn crate_src() -> &'static Path {
    Path::new("src")
}

#[test]
fn crate_sources_pass_the_lint_with_only_justified_pragmas() {
    let report = lint_path(crate_src()).expect("src must be walkable");
    assert!(report.files >= 40, "suspiciously few files scanned: {}", report.files);
    assert!(report.findings.is_empty(), "lint findings on the repo tip:\n{report}");
    // Exactly the four justified pragmas: the volatile ShardMeta
    // wall-clock in engine.rs, the two explore-report timers, and the
    // joined handler spawn in the coordinator. `energy/` and `accel/`
    // carry zero pragmas.
    assert_eq!(report.suppressed, 4, "pragma census changed:\n{report}");
}

#[test]
fn lint_reports_are_byte_identical_across_runs() {
    let a = lint_path(crate_src()).unwrap().to_string();
    let b = lint_path(crate_src()).unwrap().to_string();
    assert_eq!(a, b, "two vet runs over the same tree must render identically");
}

#[test]
fn model_checker_exhausts_the_default_space_and_proves_the_invariants() {
    let report = check(&ModelSpec::default());
    assert_eq!((report.shards, report.workers), (3, 2));
    assert!(report.exhausted, "the 3x2 space must exhaust under the state cap:\n{report}");
    assert!(report.violations.is_empty(), "{report}");
    // Both sanctioned outcomes are reachable: every shard merged, and the
    // typed dead-end where every worker exhausted its retry budget.
    assert!(report.all_done_terminals >= 1, "{report}");
    assert!(report.incomplete_terminals >= 1, "{report}");
}

/// One dataset, one base config, `cells` MACs points — the smallest space
/// that gives the replay scenarios real shards to lose.
fn replay_space(cells: usize) -> DesignSpace {
    let macs = if cells == 1 { vec![2] } else { vec![2, 4] };
    DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 64)]))
        .with_axis(Axis::macs_per_pe(macs))
}

/// A one-strike lease policy: the first reaped lease (or corrupt frame)
/// quarantines the worker, so the replayed fault class must surface as a
/// typed `Incomplete` instead of quietly re-queueing forever.
fn replay_config(shard_count: usize, max_wall_ms: u64) -> ServiceConfig {
    ServiceConfig {
        shard_count,
        lease: LeasePolicy { lease_ms: 300, max_failures: 1, ..LeasePolicy::default() },
        max_wall_ms,
        allow_partial: false,
        profile_threads: 1,
    }
}

#[test]
fn double_grant_counterexample_replays_as_a_loud_incomplete() {
    let spec = ModelSpec {
        shards: 2,
        workers: 2,
        mutation: Mutation::DoubleGrant,
        ..ModelSpec::default()
    };
    let report = check(&spec);
    let v = report.violations.first().expect("the seeded double-grant must be caught");
    assert_eq!(v.invariant, Invariant::NoLostShard, "{report}");
    assert!(!v.trace.is_empty(), "a counterexample needs a trace: {report}");
    // A pure request-interleaving counterexample maps to `stall` — the
    // dynamic trigger that makes two workers hold one shard.
    assert_eq!(v.fault_plan, "stall", "trace: {:?}", v.trace);

    let plan = FaultPlan::parse(&v.fault_plan, 7).expect("model fault plans must parse");
    let chaos =
        ChaosSpec { workers: 1, faulty: 0, plan: Some(plan), service: replay_config(2, 2500) };
    match run_chaos(&replay_space(2), &chaos, &SimEngine::new) {
        Err(ServiceError::Incomplete { completed, count, .. }) => {
            // The stalled worker's only shard still lands (stale results
            // are valid results); the second shard dies with the
            // quarantine.
            assert_eq!((completed, count), (1, 2));
        }
        Err(other) => panic!("expected Incomplete, got: {other}"),
        Ok(_) => panic!("the replay converged — the counterexample did not reproduce"),
    }
}

#[test]
fn quarantine_bypass_counterexample_replays_as_a_loud_incomplete() {
    let spec = ModelSpec {
        shards: 1,
        workers: 1,
        mutation: Mutation::QuarantineBypass,
        ..ModelSpec::default()
    };
    let report = check(&spec);
    let v = report.violations.first().expect("the seeded bypass must be caught");
    assert_eq!(v.invariant, Invariant::MergeConsistent, "{report}");
    // A divergent submission is, on the wire, a corrupted frame: the
    // plan forges the first post-register frame.
    assert_eq!(v.fault_plan, "corrupt:2", "trace: {:?}", v.trace);

    let plan = FaultPlan::parse(&v.fault_plan, 7).expect("model fault plans must parse");
    let chaos =
        ChaosSpec { workers: 1, faulty: 0, plan: Some(plan), service: replay_config(1, 2000) };
    match run_chaos(&replay_space(1), &chaos, &SimEngine::new) {
        Err(ServiceError::Incomplete { completed, count, .. }) => {
            assert_eq!((completed, count), (0, 1));
        }
        Err(other) => panic!("expected Incomplete, got: {other}"),
        Ok(_) => panic!("the replay converged — the counterexample did not reproduce"),
    }
}

#[test]
fn a_seeded_violation_fails_the_lint() {
    use maple::analysis::{lint_source, Rule};
    // The negative gate CI asserts: a fresh nondeterminism source in a
    // sim path is a finding, not a warning.
    let bad = "use std::collections::HashMap;\n";
    let lint = lint_source("sim/new_module.rs", bad);
    assert_eq!(lint.findings.len(), 1);
    assert_eq!(lint.findings[0].rule, Rule::HashIter);
    assert_eq!(lint.findings[0].line, 1);
}
