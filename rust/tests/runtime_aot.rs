//! Integration: the AOT artifacts (Pallas kernel → HLO text, built by
//! `make artifacts`) load and execute correctly through the PJRT runtime.
//!
//! These tests require `artifacts/` (build with `make artifacts`) and the
//! `runtime` cargo feature (`cargo test --features runtime`); without the
//! feature the whole file is compiled out, and without the artifacts they
//! fail with a clear message.
#![cfg(feature = "runtime")]

use maple::runtime::{artifacts_dir, LoadedModule, MapleDatapath};
use std::path::PathBuf;

fn artifacts() -> PathBuf {
    // Tests run from the crate root; fall back to $MAPLE_ARTIFACTS.
    let dir = artifacts_dir();
    assert!(
        dir.join("meta.json").exists(),
        "artifacts/ missing — run `make artifacts` before `cargo test`"
    );
    dir
}

#[test]
fn datapath_loads_and_matches_cpu_math() {
    let client = xla::PjRtClient::cpu().expect("CPU PJRT client");
    let dp = MapleDatapath::load(&client, &artifacts()).expect("artifacts load");
    let meta = dp.meta();
    assert_eq!(meta.kt, 16);
    assert_eq!(meta.nt, 128);

    // Deterministic pseudo-random tile.
    let mut rng = maple::sparse::SplitMix64::new(99);
    let a: Vec<f32> = (0..meta.kt).map(|_| rng.value()).collect();
    let b: Vec<f32> = (0..meta.kt * meta.nt).map(|_| rng.value()).collect();

    let psb = dp.run_tile(&a, &b).expect("tile executes");
    assert_eq!(psb.len(), meta.nt);
    for n in 0..meta.nt {
        let want: f32 = (0..meta.kt).map(|k| a[k] * b[k * meta.nt + n]).sum();
        assert!((psb[n] - want).abs() < 1e-4, "psb[{n}] = {} vs {want}", psb[n]);
    }
}

#[test]
fn datapath_zero_inputs_give_zero_psb() {
    let client = xla::PjRtClient::cpu().expect("CPU PJRT client");
    let dp = MapleDatapath::load(&client, &artifacts()).expect("artifacts load");
    let meta = dp.meta();
    let psb = dp.run_tile(&vec![0.0; meta.kt], &vec![0.0; meta.kt * meta.nt]).unwrap();
    assert!(psb.iter().all(|&v| v == 0.0));
}

#[test]
fn datapath_rejects_wrong_shapes() {
    let client = xla::PjRtClient::cpu().expect("CPU PJRT client");
    let dp = MapleDatapath::load(&client, &artifacts()).expect("artifacts load");
    let meta = dp.meta();
    assert!(dp.run_tile(&vec![0.0; meta.kt + 1], &vec![0.0; meta.kt * meta.nt]).is_err());
    assert!(dp.run_tile(&vec![0.0; meta.kt], &vec![0.0; 3]).is_err());
}

#[test]
fn model_artifact_loads_too() {
    let client = xla::PjRtClient::cpu().expect("CPU PJRT client");
    let m = LoadedModule::load(&client, &artifacts().join("model.hlo.txt")).expect("model loads");
    assert_eq!(m.name(), "model.hlo");
}

#[test]
fn repeated_execution_is_deterministic() {
    let client = xla::PjRtClient::cpu().expect("CPU PJRT client");
    let dp = MapleDatapath::load(&client, &artifacts()).expect("artifacts load");
    let meta = dp.meta();
    let mut rng = maple::sparse::SplitMix64::new(5);
    let a: Vec<f32> = (0..meta.kt).map(|_| rng.value()).collect();
    let b: Vec<f32> = (0..meta.kt * meta.nt).map(|_| rng.value()).collect();
    let p1 = dp.run_tile(&a, &b).unwrap();
    let p2 = dp.run_tile(&a, &b).unwrap();
    assert_eq!(p1, p2);
}
