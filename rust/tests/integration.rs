//! Cross-module integration: end-to-end simulations over Table-I workloads,
//! checking the paper's qualitative claims hold across the whole matrix of
//! (dataset family × configuration × policy).

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::gustavson::{dense_matmul, max_abs_diff, spgemm_rowwise};
use maple::sim::{profile_workload, simulate_workload};
use maple::sparse::suite;

/// One scaled dataset per structural family.
fn family_samples() -> Vec<&'static str> {
    vec!["wg", "of", "sc", "wv"]
}

#[test]
fn maple_wins_energy_on_every_family() {
    for name in family_samples() {
        let a = suite::by_name(name).unwrap().generate_scaled(7, 48);
        let w = profile_workload(&a, &a);
        for (base, maple) in [
            (AcceleratorConfig::matraptor_baseline(), AcceleratorConfig::matraptor_maple()),
            (AcceleratorConfig::extensor_baseline(), AcceleratorConfig::extensor_maple()),
        ] {
            let rb = simulate_workload(&base, &w, Policy::RoundRobin);
            let rm = simulate_workload(&maple, &w, Policy::RoundRobin);
            let benefit = rm.energy_benefit_pct(&rb);
            assert!(
                benefit > 15.0,
                "{name}/{}: energy benefit only {benefit:.1}%",
                base.name
            );
        }
    }
}

#[test]
fn maple_speedup_positive_on_every_family() {
    for name in family_samples() {
        let a = suite::by_name(name).unwrap().generate_scaled(7, 48);
        let w = profile_workload(&a, &a);
        for (base, maple) in [
            (AcceleratorConfig::matraptor_baseline(), AcceleratorConfig::matraptor_maple()),
            (AcceleratorConfig::extensor_baseline(), AcceleratorConfig::extensor_maple()),
        ] {
            let rb = simulate_workload(&base, &w, Policy::RoundRobin);
            let rm = simulate_workload(&maple, &w, Policy::RoundRobin);
            let speedup = rm.speedup_pct(&rb);
            assert!(speedup > -5.0, "{name}/{}: speedup {speedup:.1}%", base.name);
        }
    }
}

#[test]
fn paper_headline_bands_at_bench_scale() {
    // Means over the four family samples must land in the paper's
    // neighbourhood: Matraptor ≈ 50% energy / 15% speedup, Extensor ≈ 60% /
    // 22% (shape: who wins, by roughly what factor).
    let mut mat_e = Vec::new();
    let mut ext_e = Vec::new();
    for name in family_samples() {
        let a = suite::by_name(name).unwrap().generate_scaled(7, 48);
        let w = profile_workload(&a, &a);
        let mb =
            simulate_workload(&AcceleratorConfig::matraptor_baseline(), &w, Policy::RoundRobin);
        let mm = simulate_workload(&AcceleratorConfig::matraptor_maple(), &w, Policy::RoundRobin);
        let eb = simulate_workload(&AcceleratorConfig::extensor_baseline(), &w, Policy::RoundRobin);
        let em = simulate_workload(&AcceleratorConfig::extensor_maple(), &w, Policy::RoundRobin);
        mat_e.push(mm.energy_benefit_pct(&mb));
        ext_e.push(em.energy_benefit_pct(&eb));
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let (m, e) = (mean(&mat_e), mean(&ext_e));
    assert!((30.0..70.0).contains(&m), "matraptor mean energy benefit {m:.1}% (paper ~50%)");
    assert!((40.0..75.0).contains(&e), "extensor mean energy benefit {e:.1}% (paper ~60%)");
}

#[test]
fn checksum_invariant_across_configs_and_policies() {
    let a = suite::by_name("p3").unwrap().generate_scaled(3, 4);
    let w = profile_workload(&a, &a);
    let mut checksums = Vec::new();
    for cfg in AcceleratorConfig::paper_configs() {
        for policy in [Policy::RoundRobin, Policy::Chunked, Policy::GreedyBalance] {
            checksums.push(simulate_workload(&cfg, &w, policy).checksum);
        }
    }
    assert!(checksums.windows(2).all(|p| p[0] == p[1]));
}

#[test]
fn profile_checksum_equals_reference_spgemm() {
    let a = suite::by_name("fb").unwrap().generate_scaled(11, 8);
    let w = profile_workload(&a, &a);
    let c = spgemm_rowwise(&a, &a);
    let direct: f64 = c.value.iter().map(|&v| v as f64).sum();
    assert_eq!(w.out_nnz, c.nnz() as u64);
    assert!((w.checksum - direct).abs() < 1e-6 * direct.abs().max(1.0));
}

#[test]
fn small_end_to_end_against_dense_oracle() {
    // The full numeric path on a matrix small enough to densify.
    let a = suite::by_name("wv").unwrap().generate_scaled(5, 256);
    let c = spgemm_rowwise(&a, &a);
    assert!(max_abs_diff(&c, &dense_matmul(&a, &a)) < 1e-3);
}

#[test]
fn config_round_trips_through_cli_format() {
    for cfg in AcceleratorConfig::paper_configs() {
        let toml = cfg.to_toml();
        let parsed = AcceleratorConfig::from_toml(&toml).unwrap();
        assert_eq!(parsed, cfg);
        // And the parsed config simulates identically.
        let a = suite::by_name("wv").unwrap().generate_scaled(1, 64);
        let w = profile_workload(&a, &a);
        let r1 = simulate_workload(&cfg, &w, Policy::RoundRobin);
        let r2 = simulate_workload(&parsed, &w, Policy::RoundRobin);
        assert_eq!(r1.cycles_compute, r2.cycles_compute);
        assert_eq!(r1.energy.total_pj(), r2.energy.total_pj());
    }
}

#[test]
fn dram_bound_scales_with_bandwidth() {
    let a = suite::by_name("cc").unwrap().generate_scaled(2, 4);
    let w = profile_workload(&a, &a);
    let mut slow = AcceleratorConfig::extensor_maple();
    slow.dram.words_per_cycle = 4.0;
    let mut fast = AcceleratorConfig::extensor_maple();
    fast.dram.words_per_cycle = 64.0;
    let rs = simulate_workload(&slow, &w, Policy::RoundRobin);
    let rf = simulate_workload(&fast, &w, Policy::RoundRobin);
    let ratio = rs.cycles_dram_bound as f64 / rf.cycles_dram_bound as f64;
    assert!((ratio - 16.0).abs() < 0.2, "16x bandwidth must give ~16x bound, got {ratio}");
    assert_eq!(rs.cycles_compute, rf.cycles_compute, "compute model is bandwidth-independent");
}
