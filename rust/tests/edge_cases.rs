//! Edge-case and failure-injection tests: degenerate workloads, saturated
//! buffers, and hostile configurations must degrade gracefully, never
//! panic or mis-count.

use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::gustavson::spgemm_rowwise;
use maple::mem::{DramModel, DramParams, Fifo, Lane, Scratchpad};
use maple::sim::{profile_workload, simulate_workload};
use maple::sparse::gen::{generate, Profile};
use maple::sparse::Csr;
use maple::trace::Counters;

#[test]
fn empty_matrix_through_every_config() {
    let a = Csr::zero(64, 64);
    let w = profile_workload(&a, &a);
    for cfg in AcceleratorConfig::paper_configs() {
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        assert_eq!(r.counters.mac_mul, 0, "{}", cfg.name);
        assert_eq!(r.out_nnz, 0);
        // Compulsory streaming of row_ptr still costs something.
        assert!(r.energy.total_pj() > 0.0);
    }
}

#[test]
fn single_element_matrix() {
    let a = Csr::from_triplets(1, 1, vec![(0, 0, 2.0)]);
    let w = profile_workload(&a, &a);
    assert_eq!(w.total_products, 1);
    assert_eq!(w.checksum, 4.0);
    for cfg in AcceleratorConfig::paper_configs() {
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        assert_eq!(r.counters.mac_mul, 1, "{}", cfg.name);
        assert!(r.cycles_compute >= 1);
    }
}

#[test]
fn dense_row_times_dense_column_worst_case() {
    // One full row times one full column: maximal per-row products with a
    // single output element — the PSB's best case, the merge's worst.
    let n = 256;
    let mut t: Vec<(u32, u32, f32)> = (0..n).map(|j| (0u32, j as u32, 1.0)).collect();
    t.extend((0..n).map(|i| (i as u32, 0u32, 1.0)));
    let a = Csr::from_triplets(n, n, t);
    let w = profile_workload(&a, &a);
    let c = spgemm_rowwise(&a, &a);
    assert_eq!(w.out_nnz, c.nnz() as u64);
    for cfg in AcceleratorConfig::paper_configs() {
        let r = simulate_workload(&cfg, &w, Policy::GreedyBalance);
        assert_eq!(r.counters.mac_mul, w.total_products, "{}", cfg.name);
    }
}

#[test]
fn hyper_sparse_no_intersections() {
    // A's columns never hit a nonempty B row: zero products, nonzero input.
    let a = Csr::from_triplets(4, 4, vec![(0, 1, 1.0), (2, 3, 1.0)]);
    let b = Csr::from_triplets(4, 4, vec![(0, 0, 1.0), (2, 2, 1.0)]);
    let w = profile_workload(&a, &b);
    assert_eq!(w.total_products, 0);
    assert_eq!(w.out_nnz, 0);
    for cfg in AcceleratorConfig::paper_configs() {
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        assert_eq!(r.counters.mac_mul, 0, "{}", cfg.name);
    }
}

#[test]
fn one_mac_maple_degenerates_to_serial() {
    let a = generate(128, 128, 1280, Profile::Uniform, 3);
    let w = profile_workload(&a, &a);
    let mut k1 = AcceleratorConfig::matraptor_maple();
    k1.pe.macs_per_pe = 1;
    let mut k8 = AcceleratorConfig::matraptor_maple();
    k8.pe.macs_per_pe = 8;
    let r1 = simulate_workload(&k1, &w, Policy::RoundRobin);
    let r8 = simulate_workload(&k8, &w, Policy::RoundRobin);
    assert!(r1.cycles_compute > 3 * r8.cycles_compute, "k=8 must be much faster");
    assert_eq!(r1.counters, r8.counters, "MAC count must not change actions");
}

#[test]
fn pathological_config_tiny_psb_still_correct() {
    let mut cfg = AcceleratorConfig::extensor_maple();
    cfg.pe.psb_entries = 1; // absurd: one register
    let a = generate(64, 64, 640, Profile::Uniform, 9);
    let w = profile_workload(&a, &a);
    let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
    assert_eq!(r.counters.mac_mul, w.total_products);
    // Massive segmentation => massive ARB re-reads.
    assert!(r.counters.arb_read > 10 * r.counters.arb_write);
}

#[test]
fn fifo_saturation_is_observable_not_fatal() {
    let mut f = Fifo::new(4);
    let mut rejected = 0;
    for i in 0..100 {
        if f.push(i).is_err() {
            rejected += 1;
            f.pop();
            f.push(i).unwrap();
        }
    }
    assert_eq!(rejected, 96);
    assert_eq!(f.stalls(), 96);
    assert_eq!(f.high_water(), 4);
}

#[test]
fn scratchpad_overflow_spills_accounted() {
    let mut s = Scratchpad::new("LLB", Lane::L1, 1024); // 256 words
    let fit = s.allocate(1000);
    assert_eq!(fit, 256);
    assert_eq!(s.spilled_words(), 744);
    let mut c = Counters::default();
    s.read(&mut c, 10);
    assert_eq!(c.l1_read, 10);
}

#[test]
fn dram_saturation_serialises() {
    let mut d =
        DramModel::new(DramParams { words_per_cycle: 1.0, access_latency: 5, burst_words: 1 });
    let mut c = Counters::default();
    let mut done = 0u64;
    for _ in 0..100 {
        done = d.read(&mut c, 0, 10);
    }
    // 100 x 10 words at 1 word/cycle = at least 1000 cycles of port time.
    assert!(done >= 1000);
    assert_eq!(c.dram_read, 1000);
}

#[test]
fn rectangular_matrices_simulate() {
    let a = generate(64, 32, 256, Profile::Uniform, 1);
    let b = generate(32, 96, 384, Profile::Uniform, 2);
    let w = profile_workload(&a, &b);
    let c = spgemm_rowwise(&a, &b);
    assert_eq!(w.out_nnz, c.nnz() as u64);
    for cfg in AcceleratorConfig::paper_configs() {
        let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
        assert_eq!(r.counters.mac_mul, w.total_products, "{}", cfg.name);
    }
}

#[test]
fn more_pes_than_rows() {
    let a = generate(16, 16, 64, Profile::Uniform, 4);
    let w = profile_workload(&a, &a);
    let mut cfg = AcceleratorConfig::extensor_baseline(); // 128 PEs, 16 rows
    cfg.num_pes = 128;
    let r = simulate_workload(&cfg, &w, Policy::RoundRobin);
    assert_eq!(r.counters.mac_mul, w.total_products);
    assert!(r.cycles_compute > 0);
}

#[test]
fn identity_self_multiply() {
    let a = Csr::identity(512);
    let w = profile_workload(&a, &a);
    assert_eq!(w.total_products, 512);
    assert_eq!(w.out_nnz, 512);
    assert!((w.checksum - 512.0).abs() < 1e-9);
}
