//! Acceptance tests for the fault-tolerant distributed sweep service.
//!
//! The contract under test: every fault scenario ends in a bit-exact merge
//! or a loud typed error — never a hang and never a silent partial — and
//! fault injection is seed-deterministic (same plan + seed replays the
//! same event trace). Everything here runs real coordinator + worker
//! threads over loopback TCP; nothing is mocked below the socket.

use maple::config::AcceleratorConfig;
use maple::sim::cache::encode_shard;
use maple::sim::service::proto::{self, AckCode, Message};
use maple::sim::{
    run_chaos, Axis, ChaosReport, ChaosSpec, Coordinator, DesignSpace, FaultPlan, LeasePolicy,
    ServiceConfig, ServiceError, ShardSpec, SimEngine, SweepOutcome, WorkloadKey,
};

/// Six analytic cells: two datasets × one base config × three MACs points.
/// Small enough that every scenario simulates in well under a second,
/// large enough that a multi-way split gives workers real work to lose,
/// steal, and resubmit.
fn space() -> DesignSpace {
    DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![
            WorkloadKey::suite("wv", 7, 64),
            WorkloadKey::suite("fb", 7, 64),
        ]))
        .with_axis(Axis::macs_per_pe(vec![2, 4, 8]))
}

/// Tight leases (`lease_ms`) so stolen work re-queues quickly, and a far
/// wall-clock bound so only the lone-worker test ever reaches it.
fn service_config(shard_count: usize, lease_ms: u64) -> ServiceConfig {
    ServiceConfig {
        shard_count,
        lease: LeasePolicy { lease_ms, ..LeasePolicy::default() },
        max_wall_ms: 60_000,
        allow_partial: false,
        profile_threads: 1,
    }
}

#[test]
fn three_workers_one_dying_mid_lease_still_bit_identical() {
    let space = space();
    let reference = SimEngine::new().sweep(&space).unwrap();
    let spec = ChaosSpec {
        workers: 3,
        faulty: 0,
        plan: Some(FaultPlan::parse("die", 7).unwrap()),
        service: service_config(6, 400),
    };
    let chaos = run_chaos(&space, &spec, &SimEngine::new).unwrap();
    match &chaos.outcome {
        SweepOutcome::Full(grid) => assert_eq!(grid, &reference),
        other => panic!("expected a full merge, got {other:?}"),
    }
    assert_eq!(chaos.stats.completed, 6);
    assert!(
        chaos.stats.reassignments >= 1,
        "the dead worker's lease must be reaped and re-queued: {:?}",
        chaos.stats
    );
    // The dying worker reported its own demise, deterministically.
    let w0 = chaos.workers[0].as_ref().unwrap();
    assert!(w0.died, "worker 0 ran the die plan: {w0:?}");
    assert_eq!(w0.events.iter().map(|e| e.kind).collect::<Vec<_>>(), ["die"]);
}

#[test]
fn every_fault_scenario_converges_to_the_reference_grid() {
    let space = space();
    let reference = SimEngine::new().sweep(&space).unwrap();
    // One scenario per fault kind that a worker can survive: severed
    // connections, forged checksums, stalled leases, duplicate
    // submissions, kill-and-rejoin. (`die` is the lethal one; it has its
    // own tests above and below.)
    for plan in ["drop:2", "corrupt:3", "stall", "dup", "kill"] {
        let spec = ChaosSpec {
            workers: 2,
            faulty: 0,
            plan: Some(FaultPlan::parse(plan, 11).unwrap()),
            service: service_config(4, 400),
        };
        let chaos = run_chaos(&space, &spec, &SimEngine::new)
            .unwrap_or_else(|e| panic!("plan {plan}: {e}"));
        match &chaos.outcome {
            SweepOutcome::Full(grid) => assert_eq!(grid, &reference, "plan {plan}"),
            other => panic!("plan {plan}: expected a full merge, got {other:?}"),
        }
        let w0 = chaos.workers[0].as_ref().unwrap_or_else(|e| panic!("plan {plan}: {e}"));
        assert!(!w0.events.is_empty(), "plan {plan} never fired its fault");
    }
}

#[test]
fn fault_injection_is_seed_deterministic() {
    let space = space();
    let run = || {
        let spec = ChaosSpec {
            workers: 2,
            faulty: 0,
            plan: Some(FaultPlan::parse("drop:1,corrupt:3", 9).unwrap()),
            service: service_config(4, 400),
        };
        run_chaos(&space, &spec, &SimEngine::new).unwrap()
    };
    let (a, b) = (run(), run());
    let trace = |r: &ChaosReport| r.workers[0].as_ref().unwrap().events.clone();
    assert!(!trace(&a).is_empty(), "the plan must fire");
    assert_eq!(trace(&a), trace(&b), "same plan + seed must replay the same event trace");
    // Honest workers carry no trace at all.
    assert!(a.workers[1].as_ref().unwrap().events.is_empty());
    // And the faults never bent the data: both runs merged bit-exactly.
    let reference = SimEngine::new().sweep(&space).unwrap();
    for (tag, chaos) in [("first", &a), ("second", &b)] {
        match &chaos.outcome {
            SweepOutcome::Full(grid) => assert_eq!(grid, &reference, "{tag} run"),
            other => panic!("{tag} run: expected a full merge, got {other:?}"),
        }
    }
}

#[test]
fn lone_dying_worker_is_a_loud_error_not_a_hang() {
    let space = space();
    let spec = ChaosSpec {
        workers: 1,
        faulty: 0,
        plan: Some(FaultPlan::parse("die", 7).unwrap()),
        service: ServiceConfig {
            shard_count: 2,
            lease: LeasePolicy { lease_ms: 400, ..LeasePolicy::default() },
            max_wall_ms: 2_500,
            allow_partial: false,
            profile_threads: 1,
        },
    };
    let started = std::time::Instant::now();
    match run_chaos(&space, &spec, &SimEngine::new) {
        Err(ServiceError::Incomplete { completed, count, missing }) => {
            assert_eq!((completed, count), (0, 2));
            assert_eq!(missing, vec![0, 1]);
        }
        other => panic!("expected ServiceError::Incomplete, got {other:?}"),
    }
    assert!(started.elapsed().as_secs() < 30, "the bounded run must never hang");
}

/// Under `allow_partial`, a sweep that ends with shards missing renders
/// the completed sub-grid with explicit provenance instead of erroring.
/// The test plays the worker role over the raw wire protocol: register,
/// submit exactly one of two shards, let the wall-clock bound expire.
#[test]
fn allow_partial_reports_the_completed_sub_grid() {
    let space = space();
    let engine = SimEngine::new();
    let shard0 = engine.sweep_shard(&space, ShardSpec::new(0, 2).unwrap()).unwrap();
    let cfg = ServiceConfig {
        shard_count: 2,
        lease: LeasePolicy { lease_ms: 60_000, ..LeasePolicy::default() },
        max_wall_ms: 1_500,
        allow_partial: true,
        profile_threads: 1,
    };
    let coordinator = Coordinator::bind("127.0.0.1:0", cfg).unwrap();
    let addr = coordinator.local_addr().unwrap();
    let (outcome, stats) = std::thread::scope(|s| {
        let run = s.spawn(|| coordinator.run(&space));
        let mut stream = std::net::TcpStream::connect(addr).unwrap();
        proto::write_message(&mut stream, &Message::Register { worker_id: "half".into() })
            .unwrap();
        // The Space broadcast; this "worker" already knows what to compute.
        let _space_msg = proto::read_message(&mut stream).unwrap();
        proto::write_message(
            &mut stream,
            &Message::Submit { worker_id: "half".into(), shard: encode_shard(&shard0) },
        )
        .unwrap();
        let ack = proto::read_message(&mut stream).unwrap();
        assert!(
            matches!(ack, Message::Ack { code: AckCode::Accepted, .. }),
            "unexpected ack {ack:?}"
        );
        run.join().expect("coordinator panicked")
    })
    .unwrap();
    assert_eq!(stats.completed, 1);
    match outcome {
        SweepOutcome::Partial(partial) => {
            assert_eq!(partial.covered_cells(), 3);
            assert_eq!(partial.missing_cells(), 3);
            assert_eq!(partial.missing_spans, vec![3..6]);
        }
        other => panic!("expected a partial sweep, got {other:?}"),
    }
}
