//! Integration coverage for the persistent workload cache: codec property
//! round-trips over generated matrices/workloads (empty and rectangular
//! included), corruption / truncation / version-bump rejection with
//! store-level eviction-and-recompute, warm-vs-cold byte identity of a full
//! engine sweep, and the warm-start speedup acceptance gate (a warm
//! `workload()` must eliminate the synthesis + profile stage, ≥5×).
//!
//! Same property-test discipline as `proptest_invariants.rs`: no proptest
//! crate, deterministic SplitMix64-driven case sweeps, failures print the
//! offending seed.

use std::path::PathBuf;
use std::time::Instant;

use maple::sim::cache::{
    decode_csr, decode_workload, encode_csr, encode_workload, CodecError, DiskCache,
    CODEC_VERSION,
};
use maple::sim::{profile_workload, DesignSpace, SimEngine, WorkloadKey};
use maple::sparse::gen::{generate, Profile};
use maple::sparse::{Csr, SplitMix64};

/// A fresh per-test scratch cache directory (tests run concurrently in one
/// process, so the tag keeps them disjoint).
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("maple-cache-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Random CSR (possibly rectangular, possibly near-empty) from a seed.
fn arb_matrix(seed: u64) -> Csr {
    let mut r = SplitMix64::new(seed);
    let rows = 1 + r.below(80) as usize;
    let cols = 1 + r.below(80) as usize;
    let nnz = r.below((rows * cols / 2).max(1) as u64) as usize;
    let profile = match r.below(3) {
        0 => Profile::Uniform,
        1 => Profile::PowerLaw { alpha: 0.5 + r.unit_f64() },
        _ => Profile::Banded { rel_bandwidth: 0.1, cluster: 1 + r.below(4) as usize },
    };
    generate(rows, cols, nnz.max(1), profile, seed.wrapping_mul(0x9E37_79B9))
}

#[test]
fn prop_csr_codec_round_trips_bit_exact() {
    for seed in 0..60 {
        let a = arb_matrix(seed);
        let decoded = decode_csr(&encode_csr(&a)).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(decoded, a, "seed {seed}");
        // Value bits survive exactly (no float round-trip through text).
        for (dv, av) in decoded.value.iter().zip(&a.value) {
            assert_eq!(dv.to_bits(), av.to_bits(), "seed {seed}");
        }
    }
    // Degenerate shapes the generator never emits.
    for m in [Csr::zero(5, 3), Csr::zero(1, 1), Csr::identity(17)] {
        assert_eq!(decode_csr(&encode_csr(&m)).unwrap(), m);
    }
}

#[test]
fn prop_workload_codec_round_trips_bit_exact() {
    for seed in 0..40 {
        let mut r = SplitMix64::new(seed ^ 0xABCD);
        let m = 1 + r.below(50) as usize;
        let k = 1 + r.below(50) as usize;
        let n = 1 + r.below(50) as usize;
        let a = generate(m, k, (m * k / 4).max(1), Profile::PowerLaw { alpha: 0.7 }, seed);
        let b = generate(k, n, (k * n / 4).max(1), Profile::Uniform, seed + 1);
        let w = profile_workload(&a, &b);
        let decoded =
            decode_workload(&encode_workload(&w)).unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(decoded, w, "seed {seed}");
        assert_eq!(decoded.checksum.to_bits(), w.checksum.to_bits(), "seed {seed}");
    }
    // Empty workload: 5 rows of nothing.
    let z = Csr::zero(5, 5);
    let w = profile_workload(&z, &z);
    assert_eq!(decode_workload(&encode_workload(&w)).unwrap(), w);
}

#[test]
fn corruption_truncation_and_version_bump_are_rejected() {
    let a = generate(40, 40, 200, Profile::PowerLaw { alpha: 0.6 }, 2);
    let clean = encode_workload(&profile_workload(&a, &a));

    // Truncation at every prefix length must fail, never mis-decode.
    for cut in 0..clean.len() {
        assert!(decode_workload(&clean[..cut]).is_err(), "prefix of {cut} bytes accepted");
    }
    // Single-byte corruption anywhere must fail.
    for pos in 0..clean.len() {
        let mut bad = clean.clone();
        bad[pos] ^= 0x01;
        assert!(decode_workload(&bad).is_err(), "flip at byte {pos} accepted");
    }
    // A future codec version is rejected up front.
    let mut future = clean.clone();
    future[8..12].copy_from_slice(&(CODEC_VERSION + 7).to_le_bytes());
    assert!(matches!(
        decode_workload(&future),
        Err(CodecError::VersionMismatch { found, .. }) if found == CODEC_VERSION + 7
    ));
}

#[test]
fn bad_cache_file_is_evicted_and_recomputed() {
    let dir = scratch_dir("evict-recompute");
    let key = WorkloadKey::suite("wv", 7, 64);

    let cold = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let w_cold = cold.workload(&key).unwrap();
    assert_eq!((cold.profiles_run(), cold.disk_stores()), (1, 1));
    let path = cold.disk_cache().unwrap().workload_path(&key, 1);
    assert!(path.exists());

    // Corrupt the stored artifact in place.
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();

    // A fresh engine must not trust it: evict, recompute, re-publish.
    let warm = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let w2 = warm.workload(&key).unwrap();
    assert_eq!(warm.disk_hits(), 0, "corrupt artifact must read as a miss");
    assert_eq!(warm.profiles_run(), 1, "must recompute after eviction");
    assert_eq!(warm.disk_stores(), 1, "must re-publish the good artifact");
    assert_eq!(*w2, *w_cold);

    // And the re-published artifact is trusted again.
    let third = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let w3 = third.workload(&key).unwrap();
    assert_eq!((third.profiles_run(), third.disk_hits()), (0, 1));
    assert_eq!(*w3, *w_cold);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_sweep_cell_is_byte_identical_to_cold() {
    let dir = scratch_dir("warm-vs-cold");
    let spec = DesignSpace::paper(vec![
        WorkloadKey::suite("wv", 7, 64),
        WorkloadKey::suite("fb", 7, 64),
    ]);

    let cold_engine = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let cold = cold_engine.sweep(&spec).unwrap();
    assert_eq!(cold_engine.profiles_run(), 2);
    assert_eq!(cold_engine.disk_hits(), 0);

    let warm_engine = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let warm = warm_engine.sweep(&spec).unwrap();
    assert_eq!(warm_engine.profiles_run(), 0, "warm sweep must not profile");
    assert_eq!(warm_engine.disk_hits(), 2, "both datasets must load from disk");

    // `SweepResult: PartialEq` compares every cell field bit-for-bit.
    assert_eq!(cold, warm);
    for (d, c, p, r) in cold.iter() {
        assert_eq!(
            r.analytic.checksum.to_bits(),
            warm.get(d, c, p).analytic.checksum.to_bits()
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_start_eliminates_synthesis_and_profiling() {
    // The acceptance gate: on a warm run the synthesis + profile stage is
    // replaced by one artifact read, which must be at least 5× faster (in
    // practice it is orders of magnitude). wikiVote at full Table-I size:
    // ~8.3K rows, ~104K nnz, ~1.3M products cold vs a ~130 KB read warm.
    let dir = scratch_dir("speedup-gate");
    let key = WorkloadKey::suite("wv", 7, 1);

    let cold_engine = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let t0 = Instant::now();
    let w_cold = cold_engine.workload(&key).unwrap();
    let cold = t0.elapsed();
    assert_eq!((cold_engine.profiles_run(), cold_engine.disk_stores()), (1, 1));

    let warm_engine = SimEngine::new().with_disk_cache(DiskCache::new(&dir).unwrap());
    let t1 = Instant::now();
    let w_warm = warm_engine.workload(&key).unwrap();
    let warm = t1.elapsed();
    assert_eq!((warm_engine.profiles_run(), warm_engine.disk_hits()), (0, 1));

    // Byte-identical results...
    assert_eq!(*w_warm, *w_cold);
    assert_eq!(w_warm.checksum.to_bits(), w_cold.checksum.to_bits());
    // ...and the stage itself is gone.
    assert!(
        warm <= cold / 5,
        "warm start must be ≥5× faster: cold {cold:?} vs warm {warm:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn nnz_balanced_parallel_profiling_matches_serial_through_the_engine() {
    // The profile-chunk count is part of the determinism contract *and* the
    // disk key: engines at different chunk counts keep separate artifacts,
    // and each warm load reproduces its own cold bytes exactly.
    let dir = scratch_dir("chunked-profiles");
    let key = WorkloadKey::suite("wv", 11, 64);
    let serial = SimEngine::new().workload(&key).unwrap();
    for chunks in [2usize, 4, 7] {
        let cold = SimEngine::new()
            .with_profile_threads(chunks)
            .with_disk_cache(DiskCache::new(&dir).unwrap());
        let w = cold.workload(&key).unwrap();
        assert_eq!(w.profiles, serial.profiles, "chunks={chunks}");
        assert_eq!(w.out_nnz, serial.out_nnz);
        assert_eq!(w.total_products, serial.total_products);
        assert!(
            (w.checksum - serial.checksum).abs() < 1e-6 * serial.checksum.abs().max(1.0),
            "chunks={chunks}"
        );
        let warm = SimEngine::new()
            .with_profile_threads(chunks)
            .with_disk_cache(DiskCache::new(&dir).unwrap());
        let w2 = warm.workload(&key).unwrap();
        assert_eq!((warm.profiles_run(), warm.disk_hits()), (0, 1), "chunks={chunks}");
        assert_eq!(w2.checksum.to_bits(), w.checksum.to_bits(), "chunks={chunks}");
        assert_eq!(*w2, *w);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
