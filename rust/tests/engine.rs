//! Integration: the `SimEngine` sweep layer and the open PE registry.
//!
//! * rectangular `A(m×k) × B(k×n)` runs end-to-end and agrees with the
//!   reference SpGEMM (the `Workload::rows_b` fix),
//! * sweeps are deterministic in the fan-out width,
//! * a new PE plugs in through `pe::registry` without touching `accel/`.

use maple::accel::Accelerator;
use maple::config::AcceleratorConfig;
use maple::coordinator::Policy;
use maple::gustavson::{multiply_count, spgemm_rowwise};
use maple::noc::Topology;
use maple::pe::{registry, PeModel, RowCost, RowProfile};
use maple::sim::{
    profile_workload, profile_workload_parallel, simulate_spmspm, simulate_workload, Axis,
    CellModel, DesignSpace, SimEngine, WorkloadKey,
};
use maple::sparse::gen::{generate, Profile};
use maple::trace::Counters;

// --- Rectangular SpMSpM -------------------------------------------------

#[test]
fn rectangular_spmspm_end_to_end() {
    // A(120×200) × B(200×60): every dimension distinct.
    let a = generate(120, 200, 1800, Profile::PowerLaw { alpha: 0.6 }, 11);
    let b = generate(200, 60, 1500, Profile::Uniform, 13);
    let c = spgemm_rowwise(&a, &b);

    for cfg in AcceleratorConfig::paper_configs() {
        let r = simulate_spmspm(&cfg, &a, &b);
        assert_eq!(r.out_nnz, c.nnz() as u64, "{}", cfg.name);
        assert_eq!(r.total_products, multiply_count(&a, &b), "{}", cfg.name);
        let direct: f64 = c.value.iter().map(|&v| v as f64).sum();
        assert!(
            (r.checksum - direct).abs() < 1e-4 * direct.abs().max(1.0),
            "{}: checksum {} vs reference {direct}",
            cfg.name,
            r.checksum
        );
        assert!(r.cycles_compute > 0 && r.energy.total_pj() > 0.0);
    }
}

#[test]
fn rectangular_parallel_profile_matches_serial() {
    let a = generate(300, 150, 2400, Profile::PowerLaw { alpha: 0.7 }, 21);
    let b = generate(150, 400, 2000, Profile::Uniform, 23);
    let serial = profile_workload(&a, &b);
    assert_eq!(serial.rows, 300);
    assert_eq!(serial.cols, 400);
    assert_eq!(serial.rows_b, 150);
    for threads in [2, 3, 8] {
        let par = profile_workload_parallel(&a, &b, threads);
        assert_eq!(par.profiles, serial.profiles, "threads={threads}");
        assert_eq!(par.out_nnz, serial.out_nnz);
        assert_eq!(par.total_products, serial.total_products);
        assert_eq!(par.rows_b, serial.rows_b);
        assert_eq!(par.compulsory_dram_words(), serial.compulsory_dram_words());
        assert!(
            (par.checksum - serial.checksum).abs() < 1e-6 * serial.checksum.abs().max(1.0),
            "threads={threads}"
        );
    }
}

#[test]
fn rectangular_b_row_ptr_counts_b_rows() {
    // Tall-thin B: the B term of the compulsory traffic must use B's 400
    // row_ptr entries, not A's 40.
    let a = generate(40, 400, 700, Profile::Uniform, 3);
    let b = generate(400, 30, 900, Profile::Uniform, 5);
    let w = profile_workload(&a, &b);
    let expect = (2 * w.nnz_a + 41) + (2 * w.nnz_b + 401) + (2 * w.out_nnz + 41);
    assert_eq!(w.compulsory_dram_words(), expect);
}

// --- Engine determinism and cache reuse ---------------------------------

fn small_sweep() -> DesignSpace {
    DesignSpace::new(
        AcceleratorConfig::paper_configs(),
        vec![WorkloadKey::suite("wv", 7, 64), WorkloadKey::suite("fb", 7, 64)],
        vec![Policy::RoundRobin, Policy::GreedyBalance],
    )
}

#[test]
fn sweep_is_deterministic_across_thread_counts() {
    let spec = small_sweep();
    let reference = SimEngine::new().with_threads(1).sweep(&spec).unwrap();
    for threads in [2, 5, 16] {
        let grid = SimEngine::new().with_threads(threads).sweep(&spec).unwrap();
        assert_eq!(grid, reference, "threads={threads}");
    }
}

#[test]
fn des_backed_sweep_is_deterministic_and_in_band() {
    // The acceptance sweep: ≥ 2 Table-I datasets × the four paper configs
    // under `CellModel::Des` and `Both` — deterministic across fan-out
    // widths, every cell carrying a DES result that sits at or above the
    // analytic compute cycles inside the documented bracket.
    for model in [CellModel::Des, CellModel::Both] {
        let spec = small_sweep().with_cell_model(model);
        let reference = SimEngine::new().with_threads(1).sweep(&spec).unwrap();
        let wide = SimEngine::new().with_threads(8).sweep(&spec).unwrap();
        assert_eq!(reference, wide, "{model:?} grid must not depend on fan-out width");
        assert_eq!(reference.cell_count(), 2 * 4 * 2);
        for (d, c, p, cell) in reference.iter() {
            let des = cell.des.as_ref().expect("DES attached to every cell");
            assert!(
                des.cycles >= cell.analytic.cycles_compute,
                "({d},{c},{p}): DES {} under-counts analytic {}",
                des.cycles,
                cell.analytic.cycles_compute
            );
            assert_eq!(cell.des_in_band(), Some(true), "({d},{c},{p})");
            assert!(cell.agreement_ratio().unwrap() >= 1.0);
        }
        assert!(reference.des_out_of_band().is_empty());
    }
}

#[test]
fn engine_profiles_each_dataset_once_across_sweeps() {
    let engine = SimEngine::new();
    let spec = small_sweep();
    let first = engine.sweep(&spec).unwrap();
    assert_eq!(engine.profiles_run(), 2);
    // A second sweep over the same datasets is pure cache reuse …
    let second = engine.sweep(&spec).unwrap();
    assert_eq!(engine.profiles_run(), 2);
    assert_eq!(first, second);
    // … and duplicate dataset entries in one spec profile once too.
    let mut dup_keys = spec.datasets().to_vec();
    dup_keys.push(dup_keys[0].clone());
    let dup = DesignSpace::new(
        AcceleratorConfig::paper_configs(),
        dup_keys,
        vec![Policy::RoundRobin, Policy::GreedyBalance],
    );
    engine.sweep(&dup).unwrap();
    assert_eq!(engine.profiles_run(), 2);
}

#[test]
fn engine_cells_match_direct_serial_simulation() {
    let engine = SimEngine::new();
    let spec = small_sweep();
    let grid = engine.sweep(&spec).unwrap();
    // Re-derive one column of the grid the pre-engine way.
    let a = maple::sparse::suite::by_name("wv").unwrap().generate_scaled(7, 64);
    let w = profile_workload(&a, &a);
    for (ci, cfg) in spec.configs.iter().enumerate() {
        for (pi, &policy) in [Policy::RoundRobin, Policy::GreedyBalance].iter().enumerate() {
            assert_eq!(
                grid.get(0, ci, pi).analytic,
                simulate_workload(cfg, &w, policy),
                "{}/{policy:?}",
                cfg.name
            );
        }
    }
}

// --- Typed design-space axes ---------------------------------------------

#[test]
fn noc_macs_axis_sweep_end_to_end() {
    // The acceptance sweep: `--axis noc=crossbar:8,mesh:4x2 --axis
    // macs=2,4,8,16` over one base config and one dataset — deterministic
    // across fan-out widths, index-addressed, every cell carrying
    // named-axis coordinates, and each cell equal to a direct simulation
    // of the transformed config.
    let spec = DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
        .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 64)]))
        .with_axis(Axis::topology(vec![
            Topology::Crossbar { ports: 8 },
            Topology::Mesh { width: 4, height: 2 },
        ]))
        .with_axis(Axis::macs_per_pe(vec![2, 4, 8, 16]));
    let reference = SimEngine::new().with_threads(1).sweep(&spec).unwrap();
    let wide = SimEngine::new().with_threads(4).sweep(&spec).unwrap();
    assert_eq!(reference, wide, "axis grid must not depend on fan-out width");
    assert_eq!(reference.shape(), vec![1, 1, 2, 4, 1]);
    assert_eq!(reference.cell_count(), 8);

    let a = maple::sparse::suite::by_name("wv").unwrap().generate_scaled(7, 64);
    let w = profile_workload(&a, &a);
    let topologies =
        [Topology::Crossbar { ports: 8 }, Topology::Mesh { width: 4, height: 2 }];
    let macs = [2usize, 4, 8, 16];
    for (ni, &noc) in topologies.iter().enumerate() {
        for (mi, &k) in macs.iter().enumerate() {
            let cell = reference.at(&[0, 0, ni, mi, 0]);
            // Coordinates name the point.
            assert_eq!(cell.coords[2].axis, "noc");
            assert_eq!(cell.coords[2].label, noc.to_string());
            assert_eq!(cell.coords[3].axis, "macs");
            assert_eq!(cell.coords[3].label, k.to_string());
            // The cell is exactly the transformed config's simulation.
            let mut cfg = AcceleratorConfig::extensor_maple();
            cfg.noc = noc;
            cfg.pe.macs_per_pe = k;
            cfg.name = format!("extensor-maple+noc={noc}+macs={k}");
            assert_eq!(cell.analytic, simulate_workload(&cfg, &w, Policy::RoundRobin));
        }
    }
}

#[test]
fn prefetch_axis_varies_the_des_and_composes_with_cell_model() {
    // A prefetch-depth axis only matters to the DES (the analytic model
    // idealises fetch away): under CellModel::Both the analytic numbers
    // must be identical along the axis while a depth-1 loader can never
    // beat a deep one.
    let grid = SimEngine::new()
        .sweep(
            &DesignSpace::over(vec![AcceleratorConfig::extensor_maple()])
                .with_axis(Axis::Dataset(vec![WorkloadKey::suite("wv", 7, 64)]))
                .with_axis(Axis::prefetch_depth(vec![1, 6]))
                .with_cell_model(CellModel::Both),
        )
        .unwrap();
    assert_eq!(grid.shape(), vec![1, 1, 2, 1]);
    let (shallow, deep) = (grid.cell(0), grid.cell(1));
    // Identical analytic numbers (the config *names* differ by design —
    // they carry the axis coordinates).
    assert_eq!(shallow.analytic.cycles_compute, deep.analytic.cycles_compute);
    assert_eq!(shallow.analytic.counters, deep.analytic.counters);
    assert_eq!(shallow.analytic.energy, deep.analytic.energy);
    assert_eq!(shallow.analytic.checksum.to_bits(), deep.analytic.checksum.to_bits());
    let (s_des, d_des) = (shallow.des.as_ref().unwrap(), deep.des.as_ref().unwrap());
    assert!(
        s_des.cycles >= d_des.cycles,
        "depth 1 ({}) < depth 6 ({})",
        s_des.cycles,
        d_des.cycles
    );
}

// --- Open PE registry: add a PE without touching accel/ ------------------

/// A deliberately trivial fourth PE: fixed one-cycle-per-product front,
/// free back stage, MAC actions accounted like every other model.
struct DummyPe {
    macs: usize,
}

impl PeModel for DummyPe {
    fn row_cost(&self, p: &RowProfile, c: &mut Counters) -> RowCost {
        c.mac_mul += p.products;
        RowCost { front: p.products.div_ceil(self.macs as u64), back: p.out_nnz as u64 }
    }

    fn macs(&self) -> usize {
        self.macs
    }

    fn name(&self) -> &'static str {
        "dummy-test-pe"
    }
}

#[test]
fn dummy_pe_registers_without_touching_accel() {
    registry::register("dummy-test-pe", |cfg| {
        Box::new(DummyPe { macs: cfg.pe.macs_per_pe.max(1) })
    })
    .expect("fresh name registers");
    assert!(registry::names().iter().any(|n| n == "dummy-test-pe"));

    // Select it purely through configuration.
    let mut cfg = AcceleratorConfig::extensor_maple();
    cfg.name = "extensor-dummy".into();
    cfg.pe.model = Some("dummy-test-pe".into());
    assert_eq!(Accelerator::new(cfg.clone()).pe_model().name(), "dummy-test-pe");

    // And it flows through the unchanged accel/sim/engine stack end-to-end.
    let engine = SimEngine::new();
    let key = WorkloadKey::suite("wv", 7, 64);
    let r = engine.simulate(&cfg, &key, Policy::RoundRobin).unwrap();
    let w = engine.workload(&key).unwrap();
    assert_eq!(r.counters.mac_mul, w.total_products);
    assert!(r.cycles_compute > 0);

    // The TOML path selects it too.
    let round_trip = AcceleratorConfig::from_toml(&cfg.to_toml()).unwrap();
    assert_eq!(round_trip.pe.model.as_deref(), Some("dummy-test-pe"));
    assert_eq!(Accelerator::new(round_trip).pe_model().name(), "dummy-test-pe");
}
